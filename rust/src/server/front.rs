//! The micro-batching front: ONE sweeper thread draining a shared job
//! queue into coalesced engine sweeps. A [`BatchFront`] is the unit of
//! sharding — [`super::ShardedFront`] runs one per core — but is fully
//! self-contained: its own queue, its own streaming-lane hub, its own
//! pooled predict engines, sharing only the read-only `Arc<Model>`.
//!
//! Connection handlers never run the engine. They enqueue [`FrontJob`]s
//! and the sweeper drains the queue: concurrent `predict` requests
//! coalesce into one stateless [`BatchEsn`] sweep (one pass over
//! `Λ`/`[W_in]_Q` amortized across the batch, engines reused from an
//! [`EnginePool`] keyed by padded lane-width bucket), and per-connection
//! `stream`
//! states live as lanes of one persistent hub whose pending requests
//! advance together in a branchless masked sweep. Per-lane arithmetic is
//! bit-identical to the sequential engine, so batching is invisible to
//! clients.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::linalg::Mat;
use crate::readout::{acc_cost_bytes, GramAcc, GramAccRaw, Readout};
use crate::reservoir::{BatchEsn, LaneReadout};

use super::pool::EnginePool;
use super::registry::{ModelId, ModelRegistry, BASE_MODEL};
use super::{Model, Precision};

/// Max predict requests folded into one stateless sweep.
pub(crate) const MAX_PREDICT_BATCH: usize = 32;
/// Streaming-state lanes in the persistent hub (connections beyond this
/// fall back to local per-connection state).
pub(crate) const STREAM_LANES: usize = 64;
/// Queue depth at which the sweeper skips the hold-off and drains
/// immediately — the "under load" threshold.
const HOLDOFF_DRAIN_DEPTH: usize = 4;
/// Queue-admission ceiling: a submission finding this many jobs
/// already queued is shed with the typed `overloaded` error instead
/// of buffering without bound. Far above anything a healthy sweeper
/// leaves queued (it drains whole batches per round), so only a stuck
/// or saturated shard ever sheds; fault injection can force it lower.
const ADMIT_MAX_DEPTH: usize = 4096;
/// Smoothing factor for the job inter-arrival EWMA the hold-off
/// autotuner reads (`--holdoff-auto`).
const ARRIVAL_EWMA_ALPHA: f64 = 0.2;

// ---------------------------------------------------------------------------
// precision-dispatched lane engine
// ---------------------------------------------------------------------------

/// Committed-readout versions retained per lane for `rollback` — a
/// small bounded ring, so committing in a loop can never grow sweeper
/// memory without bound.
pub(crate) const VERSION_RING: usize = 8;

/// The full portable value of one streaming lane, captured by
/// `checkpoint` and reinstalled — on any lane of any hub serving the
/// same model at the same precision — by `restore`: dynamics state,
/// online-trainer accumulator, and the committed-readout version ring.
/// Every numeric field is f64 at the boundary (widening from the f32
/// hub is exact, and the JSON wire codec round-trips f64 bit-exactly),
/// so `restore(checkpoint())` reproduces the lane bit-for-bit. This is
/// both the client warm-failover token and the shard/node lane-migration
/// primitive.
#[derive(Clone, Debug, PartialEq)]
pub struct LaneSnapshot {
    /// Reservoir feature dimension `N` (validated on restore).
    pub(crate) n: usize,
    /// Serving precision the snapshot was taken at. Restore refuses a
    /// mismatch: narrowing foreign f64 state would silently round.
    pub(crate) precision: Precision,
    /// `lane_state` layout: `n_real` real slots, then (re, im) pairs.
    pub(crate) state: Vec<f64>,
    /// Online Gram accumulator, when the lane has accumulated rows.
    pub(crate) trainer: Option<GramAccRaw>,
    /// Version id of the installed committed readout; 0 = base model
    /// readout (invariant: 0 or a member of `versions`).
    pub(crate) active_version: u64,
    /// The id the next `commit` will assign (monotonic per lane; always
    /// greater than every retained id, and ≥ 1).
    pub(crate) next_version: u64,
    /// Retained version ring, oldest first: `(id, w column [N], bias)`.
    pub(crate) versions: Vec<(u64, Vec<f64>, f64)>,
}

/// A sweeper-side outcome routed back to the submitter: plain numbers
/// (predict/stream outputs, row counts, version ids), a boxed lane
/// snapshot (`checkpoint`), or a typed error code — a slug resolved
/// through `wire::coded_error`, so the threaded and event-loop
/// transports answer every failure with the identical message AND the
/// identical machine-readable `code` field.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    Vals(Vec<f64>),
    Snap(Box<LaneSnapshot>),
    Err(&'static str),
}

/// One precision's hub: the batched lane engine, the model readout
/// pre-cast to `S`, and the per-lane TRAINING state — a streaming
/// [`GramAcc`] fed by `train` ops and the committed readout installed by
/// `commit` (an `Arc` swap owned by the sweeper thread, so installation
/// is atomic with respect to every sweep).
pub(crate) struct HubState<S: crate::num::Scalar> {
    engine: BatchEsn<S>,
    ro: LaneReadout<S>,
    /// Per-lane online trainers, allocated lazily on the first `train`
    /// (each allocation charged against `trainer_budget`).
    trainers: Vec<Option<GramAcc<S>>>,
    /// Per-lane committed readouts; `None` = the shared model readout.
    /// A committed lane's streams leave the fused shared sweep and go
    /// through [`HubState::sweep_committed`].
    committed: Vec<Option<Arc<Readout>>>,
    /// Per-lane bounded ring of retained committed readouts, oldest
    /// first — `rollback` reinstalls any member atomically.
    versions: Vec<Vec<(u64, Arc<Readout>)>>,
    /// Per-lane id of the installed committed readout (0 = base model
    /// readout; otherwise a member of the lane's ring).
    active_version: Vec<u64>,
    /// Per-lane id the next `commit` assigns (monotonic, starts at 1).
    next_version: Vec<u64>,
    /// Lanes quarantined by a sweep panic: stateful ops answer
    /// `lane_poisoned` until a `reset` or `restore` rebuilds the lane.
    poisoned: Vec<bool>,
    /// Bytes currently pinned by allocated trainers.
    trainer_bytes: usize,
    /// Trainer allocation cap for this hub (`usize::MAX` = unlimited).
    trainer_budget: usize,
}

impl<S: crate::num::Scalar> HubState<S> {
    fn new(model: &Model, lanes: usize, trainer_budget: usize) -> Self {
        Self {
            engine: BatchEsn::<S>::with_precision(model.qesn.clone(), lanes),
            ro: LaneReadout::new(&model.readout),
            trainers: (0..lanes).map(|_| None).collect(),
            committed: vec![None; lanes],
            versions: (0..lanes).map(|_| Vec::new()).collect(),
            active_version: vec![0; lanes],
            next_version: vec![1; lanes],
            poisoned: vec![false; lanes],
            trainer_bytes: 0,
            trainer_budget,
        }
    }

    /// The effective trainer budget (fault injection can force a lower
    /// one to drive exhaustion deterministically in tests).
    fn budget(&self) -> usize {
        super::fault::budget_override().unwrap_or(self.trainer_budget)
    }

    /// Per-lane trainer cost under the budget model.
    fn trainer_cost(&self) -> usize {
        acc_cost_bytes(self.engine.n(), 1, std::mem::size_of::<S>())
    }

    /// Coalesced streaming sweep with per-lane readout overrides: lanes
    /// still on the model readout advance together through the engine's
    /// fused masked sweep; committed lanes advance together through
    /// [`Self::sweep_committed`]. Lane state evolution is identical
    /// either way (frozen-lane exactness + lane position independence),
    /// so the split is unobservable beyond the readout itself.
    fn sweep_streams(&mut self, reqs: &[(usize, &[f64])]) -> Vec<Vec<f64>> {
        if reqs.iter().all(|&(lane, _)| self.committed[lane].is_none()) {
            return self.engine.sweep_streams_cast(reqs, &self.ro);
        }
        let mut outs: Vec<Option<Vec<f64>>> = reqs.iter().map(|_| None).collect();
        let mut base: Vec<(usize, &[f64])> = Vec::new();
        let mut base_idx: Vec<usize> = Vec::new();
        let mut custom: Vec<(usize, &[f64])> = Vec::new();
        let mut custom_idx: Vec<usize> = Vec::new();
        for (i, &(lane, input)) in reqs.iter().enumerate() {
            if self.committed[lane].is_some() {
                custom.push((lane, input));
                custom_idx.push(i);
            } else {
                base.push((lane, input));
                base_idx.push(i);
            }
        }
        if !base.is_empty() {
            let got = self.engine.sweep_streams_cast(&base, &self.ro);
            for (i, out) in base_idx.into_iter().zip(got) {
                outs[i] = Some(out);
            }
        }
        let got = self.sweep_committed(&custom);
        for (i, out) in custom_idx.into_iter().zip(got) {
            outs[i] = Some(out);
        }
        outs.into_iter().map(|o| o.expect("every request answered")).collect()
    }

    /// Masked sweep over committed lanes: all requested lanes advance
    /// together per step (same engine arithmetic as the fused sweep);
    /// each lane's output comes from its committed readout applied to
    /// the exactly-widened lane features, bias first then ascending
    /// feature index — the shared fused accumulation contract, in f64.
    fn sweep_committed(&mut self, reqs: &[(usize, &[f64])]) -> Vec<Vec<f64>> {
        let bsz = self.engine.batch();
        let n = self.engine.n();
        let max_len = reqs.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
        let mut outs: Vec<Vec<f64>> = reqs
            .iter()
            .map(|(_, s)| Vec::with_capacity(s.len()))
            .collect();
        let mut u = vec![0.0f64; bsz];
        let mut active = vec![false; bsz];
        let mut feat = vec![0.0f64; n];
        for t in 0..max_len {
            for &(lane, input) in reqs {
                active[lane] = t < input.len();
                u[lane] = if t < input.len() { input[t] } else { 0.0 };
            }
            self.engine.step_masked(&u, &active);
            for (i, &(lane, input)) in reqs.iter().enumerate() {
                if t < input.len() {
                    self.engine.lane_state(lane, &mut feat);
                    let ro = self.committed[lane].as_ref().expect("committed lane");
                    // bias-first ascending-feature apply in f64 (feature
                    // widening is exact at both precisions, so this is
                    // well-defined engine-independently)
                    outs[i].push(ro.apply_row(&feat, 0));
                }
            }
        }
        outs
    }

    /// `train` op: advance the lane through `input` (identical state
    /// evolution to a `stream` of the same rows — masked single-lane
    /// steps) and push each step's `(features, target)` row into the
    /// lane's streaming accumulator. Returns the lane's total accumulated
    /// row count.
    fn train(&mut self, lane: usize, input: &[f64], target: &[f64]) -> Reply {
        debug_assert_eq!(input.len(), target.len());
        let bsz = self.engine.batch();
        let n = self.engine.n();
        if self.trainers[lane].is_none() {
            // first train on this lane allocates its accumulator — the
            // only trainer allocation in the hub, so charging here (and
            // in restore) bounds trainer memory exactly
            let cost = self.trainer_cost();
            if self.trainer_bytes.saturating_add(cost) > self.budget() {
                return Reply::Err("trainer_budget");
            }
            self.trainer_bytes += cost;
            self.trainers[lane] = Some(GramAcc::new(n, 1));
        }
        let Self {
            engine, trainers, ..
        } = self;
        let trainer = trainers[lane].as_mut().expect("allocated above");
        let mut u = vec![0.0f64; bsz];
        let mut active = vec![false; bsz];
        active[lane] = true;
        let mut feat = vec![0.0f64; n];
        for (&ut, &yt) in input.iter().zip(target) {
            u[lane] = ut;
            engine.step_masked(&u, &active);
            engine.lane_state(lane, &mut feat);
            trainer.push_row(&feat, std::slice::from_ref(&yt));
        }
        Reply::Vals(vec![trainer.rows() as f64])
    }

    /// `commit` op: solve the lane's accumulated ridge system natively at
    /// `S`, hot-swap the lane's readout (`Arc` swap), and retain the new
    /// readout in the lane's bounded version ring under a fresh monotonic
    /// id (answered to the client). The trainer keeps its statistics —
    /// further `train` rows extend the same stream, so a later commit
    /// refines the readout online.
    fn commit(&mut self, lane: usize, alpha: f64) -> Reply {
        match &self.trainers[lane] {
            None => Reply::Err("commit_empty"),
            Some(acc) if acc.rows() == 0 => Reply::Err("commit_empty"),
            Some(acc) => match acc.solve_scaled(alpha, 1.0) {
                Ok(ro) => {
                    let v = self.next_version[lane];
                    self.next_version[lane] += 1;
                    let ro = Arc::new(ro);
                    let ring = &mut self.versions[lane];
                    if ring.len() == VERSION_RING {
                        // evict the oldest retained version; the ACTIVE
                        // version is never evicted here, because commit
                        // installs the new id as active below
                        ring.remove(0);
                    }
                    ring.push((v, Arc::clone(&ro)));
                    self.committed[lane] = Some(ro);
                    self.active_version[lane] = v;
                    Reply::Vals(vec![v as f64])
                }
                Err(_) => Reply::Err("commit_singular"),
            },
        }
    }

    /// `rollback` op: atomically reinstall a retained committed readout
    /// (or, for `version` 0, the base model readout) WITHOUT touching the
    /// trainer — accumulated rows survive, so train → commit → rollback →
    /// train → commit keeps extending one row stream.
    fn rollback(&mut self, lane: usize, version: u64) -> Reply {
        if version == 0 {
            self.committed[lane] = None;
            self.active_version[lane] = 0;
            return Reply::Vals(vec![0.0]);
        }
        match self.versions[lane].iter().find(|(v, _)| *v == version) {
            Some((v, ro)) => {
                self.committed[lane] = Some(Arc::clone(ro));
                self.active_version[lane] = *v;
                Reply::Vals(vec![*v as f64])
            }
            None => Reply::Err("rollback_unknown_version"),
        }
    }

    /// `checkpoint` op: snapshot the lane's full portable value (exact
    /// at both precisions — see [`LaneSnapshot`]). Read-only: streaming
    /// and training continue unaffected.
    fn checkpoint(&self, lane: usize, precision: Precision) -> Reply {
        let n = self.engine.n();
        let mut state = vec![0.0f64; n];
        self.engine.lane_state(lane, &mut state);
        Reply::Snap(Box::new(LaneSnapshot {
            n,
            precision,
            state,
            trainer: self.trainers[lane].as_ref().map(|t| t.export_raw()),
            active_version: self.active_version[lane],
            next_version: self.next_version[lane],
            versions: self.versions[lane]
                .iter()
                .map(|(v, ro)| (*v, ro.w.data().to_vec(), ro.b[0]))
                .collect(),
        }))
    }

    /// `restore` op: validate the snapshot fully, then install it
    /// atomically — state, trainer, version ring, active readout — and
    /// clear any poison quarantine (restore IS the recovery path after a
    /// contained sweeper panic). Nothing is modified on any validation
    /// failure, so a rejected restore leaves the lane exactly as it was.
    fn restore(
        &mut self,
        lane: usize,
        snap: &LaneSnapshot,
        precision: Precision,
    ) -> Reply {
        let n = self.engine.n();
        if snap.n != n
            || snap.precision != precision
            || snap.state.len() != n
            || snap.state.iter().any(|v| !v.is_finite())
            || snap.next_version == 0
            || snap.versions.len() > VERSION_RING
        {
            return Reply::Err("restore_mismatch");
        }
        // version-ring invariants: ids strictly ascending, all below the
        // next-id counter, weights well-formed and finite
        let mut prev = 0u64;
        for (v, w, b) in &snap.versions {
            if *v <= prev
                || *v >= snap.next_version
                || w.len() != n
                || w.iter().any(|x| !x.is_finite())
                || !b.is_finite()
            {
                return Reply::Err("restore_mismatch");
            }
            prev = *v;
        }
        if snap.active_version != 0
            && !snap
                .versions
                .iter()
                .any(|(v, _, _)| *v == snap.active_version)
        {
            return Reply::Err("restore_mismatch");
        }
        let trainer = match &snap.trainer {
            None => None,
            Some(raw) => {
                if raw.f != n || raw.d != 1 {
                    return Reply::Err("restore_mismatch");
                }
                match GramAcc::<S>::from_raw(raw) {
                    Ok(acc) => Some(acc),
                    Err(_) => return Reply::Err("restore_mismatch"),
                }
            }
        };
        // budget: the lane's current trainer charge is swapped for the
        // snapshot's (same dims, same cost), so only None↔Some changes it
        let cost = self.trainer_cost();
        let old = if self.trainers[lane].is_some() { cost } else { 0 };
        let new = if trainer.is_some() { cost } else { 0 };
        if self.trainer_bytes - old + new > self.budget() {
            return Reply::Err("trainer_budget");
        }
        let ring: Vec<(u64, Arc<Readout>)> = snap
            .versions
            .iter()
            .map(|(v, w, b)| {
                (
                    *v,
                    Arc::new(Readout {
                        w: Mat::from_rows(n, 1, w),
                        b: vec![*b],
                    }),
                )
            })
            .collect();
        // everything validated — install (the sweeper thread owns the
        // hub, so nothing observes a half-installed lane)
        self.trainer_bytes = self.trainer_bytes - old + new;
        self.engine.reset_lane(lane);
        self.engine.set_lane_state(lane, &snap.state);
        self.trainers[lane] = trainer;
        self.committed[lane] = if snap.active_version == 0 {
            None
        } else {
            ring.iter()
                .find(|(v, _)| *v == snap.active_version)
                .map(|(_, ro)| Arc::clone(ro))
        };
        self.versions[lane] = ring;
        self.active_version[lane] = snap.active_version;
        self.next_version[lane] = snap.next_version;
        self.poisoned[lane] = false;
        Reply::Vals(vec![snap.active_version as f64])
    }

    /// Full per-lane clear: zero the state AND drop the trainer, the
    /// committed readout, the version ring, and any poison quarantine.
    /// Used for both the client-visible `reset` and lane recycling —
    /// either way the lane leaves as a pristine model-readout lane, so
    /// the next owner can never inherit another connection's training.
    fn reset_lane(&mut self, lane: usize) {
        self.engine.reset_lane(lane);
        if self.trainers[lane].take().is_some() {
            let cost = self.trainer_cost();
            self.trainer_bytes = self.trainer_bytes.saturating_sub(cost);
        }
        self.committed[lane] = None;
        self.versions[lane].clear();
        self.active_version[lane] = 0;
        self.next_version[lane] = 1;
        self.poisoned[lane] = false;
    }

    fn reset(&mut self) {
        self.engine.reset();
        for t in self.trainers.iter_mut() {
            *t = None;
        }
        for c in self.committed.iter_mut() {
            *c = None;
        }
        for v in self.versions.iter_mut() {
            v.clear();
        }
        self.active_version.fill(0);
        self.next_version.fill(1);
        self.poisoned.fill(false);
        self.trainer_bytes = 0;
    }
}

/// A [`BatchEsn`] at the model's serving precision, paired with the
/// readout pre-cast to that precision (so per-round sweeps stay
/// allocation-free) and the per-lane training state. All `BatchEsn` APIs
/// are f64 at the boundary, so dispatch is a plain match.
pub(crate) enum Hub {
    F64(HubState<f64>),
    F32(HubState<f32>),
}

impl Hub {
    pub(crate) fn new(model: &Model, lanes: usize, trainer_budget: usize) -> Self {
        match model.precision {
            Precision::F64 => Hub::F64(HubState::new(model, lanes, trainer_budget)),
            Precision::F32 => Hub::F32(HubState::new(model, lanes, trainer_budget)),
        }
    }

    /// NUMA first-touch: fault the engine planes in from the calling
    /// thread (see [`BatchEsn::first_touch`]). Called at hub mint time on
    /// a core-pinned sweeper so the planes it will sweep forever are
    /// homed on its own node.
    pub(crate) fn first_touch(&mut self) {
        match self {
            Hub::F64(h) => h.engine.first_touch(),
            Hub::F32(h) => h.engine.first_touch(),
        }
    }

    pub(crate) fn sweep_streams(&mut self, reqs: &[(usize, &[f64])]) -> Vec<Vec<f64>> {
        match self {
            Hub::F64(h) => h.sweep_streams(reqs),
            Hub::F32(h) => h.sweep_streams(reqs),
        }
    }

    pub(crate) fn run_readout(&mut self, u: &Mat) -> Mat {
        match self {
            Hub::F64(h) => h.engine.run_readout_cast(u, &h.ro),
            Hub::F32(h) => h.engine.run_readout_cast(u, &h.ro),
        }
    }

    pub(crate) fn train(&mut self, lane: usize, input: &[f64], target: &[f64]) -> Reply {
        match self {
            Hub::F64(h) => h.train(lane, input, target),
            Hub::F32(h) => h.train(lane, input, target),
        }
    }

    pub(crate) fn commit(&mut self, lane: usize, alpha: f64) -> Reply {
        match self {
            Hub::F64(h) => h.commit(lane, alpha),
            Hub::F32(h) => h.commit(lane, alpha),
        }
    }

    pub(crate) fn rollback(&mut self, lane: usize, version: u64) -> Reply {
        match self {
            Hub::F64(h) => h.rollback(lane, version),
            Hub::F32(h) => h.rollback(lane, version),
        }
    }

    pub(crate) fn checkpoint(&self, lane: usize) -> Reply {
        match self {
            Hub::F64(h) => h.checkpoint(lane, Precision::F64),
            Hub::F32(h) => h.checkpoint(lane, Precision::F32),
        }
    }

    pub(crate) fn restore(&mut self, lane: usize, snap: &LaneSnapshot) -> Reply {
        match self {
            Hub::F64(h) => h.restore(lane, snap, Precision::F64),
            Hub::F32(h) => h.restore(lane, snap, Precision::F32),
        }
    }

    /// Quarantine a lane after a contained sweep panic: its hub state
    /// may be mid-update, so stateful ops answer `lane_poisoned` until
    /// a `reset` or `restore` rebuilds the lane from scratch.
    pub(crate) fn poison(&mut self, lane: usize) {
        match self {
            Hub::F64(h) => h.poisoned[lane] = true,
            Hub::F32(h) => h.poisoned[lane] = true,
        }
    }

    pub(crate) fn poisoned(&self, lane: usize) -> bool {
        match self {
            Hub::F64(h) => h.poisoned[lane],
            Hub::F32(h) => h.poisoned[lane],
        }
    }

    pub(crate) fn reset_lane(&mut self, lane: usize) {
        match self {
            Hub::F64(h) => h.reset_lane(lane),
            Hub::F32(h) => h.reset_lane(lane),
        }
    }

    /// Zero every lane (and drop all per-lane training state) — a pooled
    /// engine is reset on checkout so reuse is indistinguishable from a
    /// fresh construction.
    pub(crate) fn reset(&mut self) {
        match self {
            Hub::F64(h) => h.reset(),
            Hub::F32(h) => h.reset(),
        }
    }

    /// Lane capacity of this engine (pooled engines are bucket-width, so
    /// callers sizing a full-sweep input must use this, not their chunk
    /// length).
    pub(crate) fn lanes(&self) -> usize {
        match self {
            Hub::F64(h) => h.engine.batch(),
            Hub::F32(h) => h.engine.batch(),
        }
    }
}

// ---------------------------------------------------------------------------
// reply plumbing
// ---------------------------------------------------------------------------

/// What a queued job's reply routes back as. Channel replies (the
/// threaded path) carry only success — a dropped sender is observed as a
/// `RecvError` on the paired receiver. Event replies make the same two
/// outcomes explicit so the poll loop can dispatch without blocking.
pub(crate) enum Completion {
    /// The sweeper ran the job; here is its outcome (values, snapshot,
    /// or typed error code).
    Done(Reply),
    /// The job was dropped without running (sweeper gone / shutting
    /// down / unwound by a contained panic). The receiver falls back
    /// exactly like a `RecvError`.
    Dropped,
}

/// Completion mailbox between sweeper threads and an event loop: the
/// sweeper pushes `(token, completion)` pairs and fires the wake
/// callback (the poll loop's eventfd), and the poll thread drains the
/// batch on wake. One queue serves every shard — tokens identify the
/// request, not the shard.
pub(crate) struct CompletionQueue {
    done: Mutex<Vec<(u64, Completion)>>,
    wake: Box<dyn Fn() + Send + Sync>,
}

impl CompletionQueue {
    pub(crate) fn new(wake: Box<dyn Fn() + Send + Sync>) -> Arc<Self> {
        Arc::new(Self {
            done: Mutex::new(Vec::new()),
            wake,
        })
    }

    fn push(&self, token: u64, c: Completion) {
        // transition-edge wake: the poll thread drains the whole queue
        // per wake, so only the empty→non-empty push needs to signal —
        // a sweeper resolving a 32-predict chunk costs one eventfd
        // write, not 32. (Atomic under the mutex: a drain empties the
        // queue atomically, so any push it misses sees empty and
        // signals.)
        let was_empty = {
            let mut q = self.done.lock().unwrap();
            let was = q.is_empty();
            q.push((token, c));
            was
        };
        if was_empty {
            (self.wake)();
        }
    }

    /// Take everything completed since the last drain.
    pub(crate) fn drain(&self) -> Vec<(u64, Completion)> {
        std::mem::take(&mut *self.done.lock().unwrap())
    }
}

/// Event-loop reply handle: delivers exactly one [`Completion`] to its
/// queue — `Done` when the sweeper sends, `Dropped` from `Drop` if the
/// job dies unsent (queue cleared on sweeper death, or `submit` refusing
/// on shutdown). The exactly-once guarantee is what lets the poll loop
/// register a pending response slot unconditionally: no reply can leak.
pub(crate) struct EventReply {
    token: u64,
    queue: Arc<CompletionQueue>,
    sent: bool,
}

impl EventReply {
    pub(crate) fn new(token: u64, queue: Arc<CompletionQueue>) -> Self {
        Self {
            token,
            queue,
            sent: false,
        }
    }

    fn complete(mut self, v: Reply) {
        self.sent = true;
        self.queue.push(self.token, Completion::Done(v));
    }
}

impl Drop for EventReply {
    fn drop(&mut self) {
        if !self.sent {
            self.queue.push(self.token, Completion::Dropped);
        }
    }
}

/// Where a job's output goes: a blocking mpsc channel (one parked
/// handler thread per request — the threaded path) or an event-loop
/// completion token (no thread parks anywhere — the epoll path). The
/// sweeper is oblivious: it calls [`ReplySender::send`] either way.
pub(crate) enum ReplySender {
    Chan(mpsc::Sender<Reply>),
    Event(EventReply),
}

impl ReplySender {
    pub(crate) fn send(self, v: Reply) {
        match self {
            ReplySender::Chan(tx) => {
                let _ = tx.send(v);
            }
            ReplySender::Event(ev) => ev.complete(v),
        }
    }
}

// ---------------------------------------------------------------------------
// sweeper core pinning
// ---------------------------------------------------------------------------

/// Pin the calling thread to one CPU core via raw `sched_setaffinity`
/// — the same no-new-crates libc FFI idiom as the poll loop's epoll
/// shim. Returns `false` (thread left unpinned) when the syscall fails
/// or on non-Linux targets: pinning is a best-effort cache-locality
/// hint for the sweeper's hot planes, never a correctness requirement.
#[cfg(target_os = "linux")]
pub(crate) fn pin_current_thread(core: usize) -> bool {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u8) -> i32;
    }
    // 1024-bit mask, the kernel's default cpu_set_t width; wrap rather
    // than overflow if asked for a core beyond it
    let mut mask = [0u8; 128];
    let bit = core % (mask.len() * 8);
    mask[bit / 8] |= 1 << (bit % 8);
    // pid 0 = the calling thread
    unsafe { sched_setaffinity(0, mask.len(), mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn pin_current_thread(_core: usize) -> bool {
    false
}

// ---------------------------------------------------------------------------
// micro-batching front
// ---------------------------------------------------------------------------

/// Every job carries the [`ModelId`] it targets, captured at SUBMIT
/// time: lane jobs bake in the lane's binding the moment they enter the
/// queue, so a lane released and re-bound to another tenant while jobs
/// are still queued routes each queued job to the hub that owned the
/// lane when the client sent it — never to the new tenant's state.
pub(crate) enum FrontJob {
    Predict {
        model: ModelId,
        /// Shared, not owned: the submitter keeps a clone of the `Arc`
        /// for its dead-sweeper fallback, so queueing a predict never
        /// copies the input.
        input: Arc<Vec<f64>>,
        reply: ReplySender,
    },
    Stream {
        model: ModelId,
        lane: usize,
        input: Vec<f64>,
        reply: ReplySender,
    },
    /// Online training step(s) on a hub lane: advance the lane state over
    /// `input` and stream each step's `(features, target)` row into the
    /// lane's Gram accumulator. Answered with `[total_rows]`.
    Train {
        model: ModelId,
        lane: usize,
        input: Vec<f64>,
        target: Vec<f64>,
        reply: ReplySender,
    },
    /// Solve the lane's accumulated ridge system and hot-swap the lane's
    /// readout. Answered with `[version]` or a typed error code.
    Commit {
        model: ModelId,
        lane: usize,
        alpha: f64,
        reply: ReplySender,
    },
    /// Atomically reinstall a retained committed-readout version (0 =
    /// base model readout) without touching the trainer. Answered with
    /// `[version]` or `rollback_unknown_version`.
    Rollback {
        model: ModelId,
        lane: usize,
        version: u64,
        reply: ReplySender,
    },
    /// Snapshot the lane's full portable value. Answered with a boxed
    /// [`LaneSnapshot`].
    Checkpoint {
        model: ModelId,
        lane: usize,
        reply: ReplySender,
    },
    /// Validate and atomically install a snapshot onto the lane (also
    /// clears poison — the post-panic recovery op). Answered with
    /// `[active_version]` or a typed error code.
    Restore {
        model: ModelId,
        lane: usize,
        snap: Box<LaneSnapshot>,
        reply: ReplySender,
    },
    /// Zero a hub lane (state + trainer + committed readout + version
    /// ring). `reply` is `Some` for a client-visible `reset` (answered
    /// with an empty vec on completion), `None` when recycling a
    /// released lane.
    Reset {
        model: ModelId,
        lane: usize,
        reply: Option<ReplySender>,
    },
}

impl FrontJob {
    /// The `(model, hub lane)` a job touches (`None` for stateless
    /// predicts) — the quarantine set when a sweep panics mid-batch.
    fn lane(&self) -> Option<(ModelId, usize)> {
        match self {
            FrontJob::Predict { .. } => None,
            FrontJob::Stream { model, lane, .. }
            | FrontJob::Train { model, lane, .. }
            | FrontJob::Commit { model, lane, .. }
            | FrontJob::Rollback { model, lane, .. }
            | FrontJob::Checkpoint { model, lane, .. }
            | FrontJob::Restore { model, lane, .. }
            | FrontJob::Reset { model, lane, .. } => Some((*model, *lane)),
        }
    }

    /// Answer the job with a typed error WITHOUT running it — the
    /// admission-control / deadline-shedding path. A refused job never
    /// touches hub state, so shedding is invisible to the lane's value:
    /// the client's retried op continues the stream bit-identically.
    fn refuse(self, code: &'static str) {
        match self {
            FrontJob::Predict { reply, .. }
            | FrontJob::Stream { reply, .. }
            | FrontJob::Train { reply, .. }
            | FrontJob::Commit { reply, .. }
            | FrontJob::Rollback { reply, .. }
            | FrontJob::Checkpoint { reply, .. }
            | FrontJob::Restore { reply, .. } => reply.send(Reply::Err(code)),
            FrontJob::Reset { reply, .. } => {
                if let Some(tx) = reply {
                    tx.send(Reply::Err(code));
                }
            }
        }
    }
}

/// A queued job plus its admission deadline. The sweeper refuses (with
/// the typed `deadline_exceeded` code) any job whose deadline passed
/// while it waited in the queue — BEFORE touching lane state, so an
/// expired op is indistinguishable from one never sent.
struct QueuedJob {
    job: FrontJob,
    deadline: Option<Instant>,
}

/// The sweeper's set of per-model streaming hubs: the base hub (always
/// present — the zero-tenant fast path pays nothing for multi-tenancy)
/// plus lazily built tenant hubs keyed by [`ModelId`]. A tenant hub is
/// constructed from the registry's shared `Arc<Model>` on first use —
/// its diagonal planes are the registry's CoW copies, only the per-lane
/// state is new — and dropped once its model is deleted AND no lane is
/// still bound to it (a bound lane keeps serving off the cached planes
/// until released, per the registry's delete contract).
struct HubSet {
    base: Hub,
    tenants: HashMap<ModelId, Hub>,
    registry: Option<Arc<ModelRegistry>>,
    trainer_budget: usize,
    /// NUMA first-touch at mint time: set when this sweeper is pinned to
    /// a core, so every hub's planes are page-faulted in FROM the pinned
    /// thread (first-touch policy homes them on its node) instead of
    /// whichever thread's sweep happens to write them first.
    first_touch: bool,
}

impl HubSet {
    fn new(
        base_model: &Model,
        registry: Option<Arc<ModelRegistry>>,
        trainer_budget: usize,
        first_touch: bool,
    ) -> Self {
        let mut base = Hub::new(base_model, STREAM_LANES, trainer_budget);
        if first_touch {
            base.first_touch();
        }
        Self {
            base,
            tenants: HashMap::new(),
            registry,
            trainer_budget,
            first_touch,
        }
    }

    /// The hub serving `model` — the base hub for [`BASE_MODEL`], a
    /// cached tenant hub, or a fresh one minted from the registry.
    /// `None` means the model is unknown (never created, or deleted and
    /// already pruned): the caller answers the typed `unknown_model`.
    fn hub_for(&mut self, model: ModelId) -> Option<&mut Hub> {
        if model == BASE_MODEL {
            return Some(&mut self.base);
        }
        if !self.tenants.contains_key(&model) {
            let m = self.registry.as_ref()?.get(model)?;
            let mut hub = Hub::new(&m, STREAM_LANES, self.trainer_budget);
            if self.first_touch {
                // tenant hubs mint lazily ON the sweeper thread, so the
                // same first-touch pass homes them correctly too
                hub.first_touch();
            }
            self.tenants.insert(model, hub);
        }
        self.tenants.get_mut(&model)
    }

    /// Quarantine a lane after a contained panic — in the hub of the
    /// model the job was stamped with (if that hub still exists; a
    /// never-built hub has no state to protect).
    fn poison(&mut self, model: ModelId, lane: usize) {
        if model == BASE_MODEL {
            self.base.poison(lane);
        } else if let Some(hub) = self.tenants.get_mut(&model) {
            hub.poison(lane);
        }
    }

    /// Drop cached hubs whose model has been deleted from the registry
    /// and that no lane is still bound to. Called once per drained
    /// batch, and only when tenant hubs exist — the zero-tenant path
    /// never takes the registry lock.
    fn prune(&mut self, lane_model: &[AtomicU64]) {
        if self.tenants.is_empty() {
            return;
        }
        let Some(reg) = self.registry.as_ref() else {
            return;
        };
        let live = reg.ids();
        self.tenants.retain(|id, _| {
            live.binary_search(id).is_ok()
                || lane_model
                    .iter()
                    .any(|m| m.load(Ordering::Relaxed) == *id)
        });
    }
}

struct FrontState {
    jobs: Vec<QueuedJob>,
    shutdown: bool,
}

/// Shared queue between connection handlers and the sweeper thread —
/// one shard of the serving path (a [`super::ShardedFront`] owns `S` of
/// these; a single one is the legacy single-core front).
pub struct BatchFront {
    pub(crate) model: Arc<Model>,
    /// Multi-tenant model registry this front serves from (`None` =
    /// legacy single-model front; every model-addressed op except
    /// `BASE_MODEL` answers `unknown_model`).
    registry: Option<Arc<ModelRegistry>>,
    /// Per-lane model binding ([`BASE_MODEL`] when free or bound to the
    /// base model). Written by the wire layer at lane acquisition, read
    /// at job-submit time to stamp each lane job with its model — and by
    /// `info` for per-model lane accounting.
    lane_model: Vec<AtomicU64>,
    /// Core this front's sweeper is pinned to (`usize::MAX` = unpinned).
    pinned_core: AtomicUsize,
    state: Mutex<FrontState>,
    cv: Condvar,
    free_lanes: Mutex<Vec<usize>>,
    sweeper: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Coalescing window: with a shallow queue the sweeper waits up to
    /// this long for more jobs before draining; zero = drain immediately.
    /// In autotuned mode this is the CAP the derived window never
    /// exceeds.
    holdoff: Duration,
    /// Opt-in hold-off autotuning (`--holdoff-auto`): the sweeper sizes
    /// its coalescing window from the arrival EWMA below instead of
    /// using `holdoff` verbatim.
    holdoff_auto: AtomicBool,
    /// EWMA of observed job inter-arrival gaps (µs; f64 bit pattern) —
    /// the feed-rate signal the autotuner reads.
    arrival_ewma_us: AtomicU64,
    /// Instant of the most recent job arrival, as µs since `epoch`
    /// (`u64::MAX` = no job has ever arrived).
    last_arrival_us: AtomicU64,
    /// Time origin for the lock-free arrival clock.
    epoch: Instant,
    /// Total sweep rounds drained (metrics; exported via `info`).
    sweeps: AtomicU64,
    /// Distinct predict engines constructed by the sweeper's pool so far
    /// (metrics: stays flat once every chunk size has been seen).
    engines_built: AtomicU64,
    /// Mirror of `state.jobs.len()`, maintained under the state lock but
    /// readable without it — the sharded front's least-loaded deal polls
    /// every shard's depth per predict, which must not contend with
    /// submitters and sweepers on the queue mutex.
    depth: AtomicUsize,
    /// Sweep panics contained (lane quarantined, sweeper restarted in
    /// place) since start — metrics, and the chaos suite's containment
    /// witness.
    panics: AtomicU64,
    /// Trainer allocation cap handed to the hub (bytes; `usize::MAX` =
    /// unlimited).
    trainer_budget: usize,
    /// Jobs shed at admission with the typed `overloaded` error.
    jobs_shed: AtomicU64,
    /// Jobs refused with the typed `deadline_exceeded` error — at
    /// admission or by the sweeper when the queue outlived them.
    deadline_misses: AtomicU64,
    /// This front's sweeper thread name; fault injection scopes the
    /// admission-depth override by it, exactly like the sweeper fuse.
    sweeper_name: String,
}

impl BatchFront {
    /// Spawn the sweeper and return the shared front (no hold-off: every
    /// wake drains immediately — the legacy behavior).
    pub fn start(model: Arc<Model>) -> Arc<Self> {
        Self::start_with_holdoff(model, 0)
    }

    /// Spawn the sweeper with an adaptive micro-batch hold-off window:
    /// when fewer than a handful of jobs are queued, the sweeper waits up
    /// to `holdoff_us` µs for more to coalesce; under load (queue already
    /// batch-worthy) or on shutdown it drains immediately.
    pub fn start_with_holdoff(model: Arc<Model>, holdoff_us: u64) -> Arc<Self> {
        Self::start_configured(model, holdoff_us, "lr-batch-sweeper".into(), usize::MAX)
    }

    /// [`Self::start_with_holdoff`] with an explicit sweeper thread name
    /// (the sharded front names each shard's sweeper by index) and a
    /// per-hub trainer memory budget in bytes (`usize::MAX` =
    /// unlimited).
    pub(crate) fn start_configured(
        model: Arc<Model>,
        holdoff_us: u64,
        thread_name: String,
        trainer_budget: usize,
    ) -> Arc<Self> {
        Self::start_full(model, None, holdoff_us, thread_name, trainer_budget, None)
    }

    /// The full constructor: [`Self::start_configured`] plus the shared
    /// multi-tenant [`ModelRegistry`] this front serves from (`None` =
    /// single-model legacy front) and an optional CPU core to pin the
    /// sweeper thread to (best-effort; `info` reports whether it took).
    pub(crate) fn start_full(
        model: Arc<Model>,
        registry: Option<Arc<ModelRegistry>>,
        holdoff_us: u64,
        thread_name: String,
        trainer_budget: usize,
        pin_core: Option<usize>,
    ) -> Arc<Self> {
        let front = Arc::new(Self {
            model,
            registry,
            lane_model: (0..STREAM_LANES)
                .map(|_| AtomicU64::new(BASE_MODEL))
                .collect(),
            pinned_core: AtomicUsize::new(usize::MAX),
            state: Mutex::new(FrontState {
                jobs: Vec::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            // lane 0 handed out first
            free_lanes: Mutex::new((0..STREAM_LANES).rev().collect()),
            sweeper: Mutex::new(None),
            holdoff: Duration::from_micros(holdoff_us),
            holdoff_auto: AtomicBool::new(false),
            arrival_ewma_us: AtomicU64::new(0),
            last_arrival_us: AtomicU64::new(u64::MAX),
            epoch: Instant::now(),
            sweeps: AtomicU64::new(0),
            engines_built: AtomicU64::new(0),
            depth: AtomicUsize::new(0),
            panics: AtomicU64::new(0),
            trainer_budget,
            jobs_shed: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            sweeper_name: thread_name.clone(),
        });
        let worker = Arc::clone(&front);
        let handle = std::thread::Builder::new()
            .name(thread_name)
            .spawn(move || {
                if let Some(core) = pin_core {
                    if pin_current_thread(core) {
                        worker.pinned_core.store(core, Ordering::Relaxed);
                    }
                }
                // last-resort containment: per-batch panics are caught
                // INSIDE sweeper_loop (lane quarantine + in-place
                // restart); only a panic outside batch processing — or
                // an injected hard kill — lands here. Mark the front
                // dead and drop stranded jobs so blocked reply
                // receivers unblock into their fallbacks.
                let res = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| worker.sweeper_loop()),
                );
                let mut st = worker.state.lock().unwrap();
                st.shutdown = true;
                st.jobs.clear();
                worker.depth.store(0, Ordering::Relaxed);
                drop(st);
                if res.is_err() {
                    eprintln!("lr-batch-sweeper died; serving falls back to direct compute");
                }
            })
            .expect("spawn sweeper");
        *front.sweeper.lock().unwrap() = Some(handle);
        front
    }

    /// Stop the sweeper once the queue drains (idempotent). Jobs already
    /// queued are still processed — shutdown wakes the sweeper, which
    /// drains the queue before exiting, so no accepted job is dropped.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
        if let Some(h) = self.sweeper.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    /// Enqueue a job. Returns `false` (job dropped) when the sweeper is
    /// gone — callers use their fallback path instead of blocking.
    fn submit(&self, job: FrontJob) -> bool {
        self.submit_with_deadline(job, None)
    }

    /// Enqueue a job under admission control. Returns `false` only when
    /// the sweeper is gone (callers fall back); a job SHED at admission
    /// — queue over the depth ceiling, or deadline already expired —
    /// answers its reply with the typed `overloaded` /
    /// `deadline_exceeded` code and counts as handled (`true`): the
    /// degradation is a bounded response, never a drop or a hang.
    ///
    /// Internal lane-recycling resets (`Reset { reply: None }`) bypass
    /// the depth ceiling: refusing one would return a lane to the free
    /// list un-zeroed, handing the next owner this connection's state.
    fn submit_with_deadline(
        &self,
        job: FrontJob,
        deadline: Option<Instant>,
    ) -> bool {
        let recycle = matches!(&job, FrontJob::Reset { reply: None, .. });
        if !recycle && self.holdoff_auto.load(Ordering::Relaxed) {
            self.record_arrival();
        }
        {
            let mut st = self.state.lock().unwrap();
            if st.shutdown {
                return false;
            }
            if !recycle && st.jobs.len() >= self.admit_depth() {
                drop(st);
                self.jobs_shed.fetch_add(1, Ordering::Relaxed);
                job.refuse("overloaded");
                return true;
            }
            // non-strict so `deadline_ms: 0` expires deterministically
            if deadline.is_some_and(|d| Instant::now() >= d) {
                drop(st);
                self.deadline_misses.fetch_add(1, Ordering::Relaxed);
                job.refuse("deadline_exceeded");
                return true;
            }
            st.jobs.push(QueuedJob { job, deadline });
            self.depth.store(st.jobs.len(), Ordering::Relaxed);
        }
        self.cv.notify_all();
        true
    }

    /// Effective queue-admission ceiling (fault injection can force it
    /// lower — scoped by sweeper name — to drive typed shedding
    /// deterministically in tests).
    fn admit_depth(&self) -> usize {
        super::fault::admit_depth_override_for(&self.sweeper_name)
            .unwrap_or(ADMIT_MAX_DEPTH)
    }

    pub(crate) fn acquire_lane(&self) -> Option<usize> {
        self.free_lanes.lock().unwrap().pop()
    }

    /// Bind a hub lane to a model: every subsequently submitted lane job
    /// is stamped with (and routed to) this model's hub. Called by the
    /// wire layer right after [`Self::acquire_lane`] — before any job
    /// for the lane can be queued — so no job races the binding.
    pub(crate) fn bind_lane_model(&self, lane: usize, model: ModelId) {
        self.lane_model[lane].store(model, Ordering::Relaxed);
    }

    /// The model a hub lane is currently bound to ([`BASE_MODEL`] when
    /// free or base-bound). The migration path copies this to the
    /// destination shard before restoring the snapshot.
    pub(crate) fn lane_model_of(&self, lane: usize) -> ModelId {
        self.lane_model[lane].load(Ordering::Relaxed)
    }

    /// Queue a zeroing of the lane, THEN return it to the free list — the
    /// queue is processed in submission order, so the next owner's first
    /// request always sees a fresh state. The reset job is stamped with
    /// the lane's CURRENT binding (it must zero the hub the state lives
    /// in), and the binding is cleared only after the job is queued.
    ///
    /// If the reset cannot be queued (sweeper gone or shutting down) the
    /// lane is WITHHELD from the free list: the hub state can only be
    /// zeroed by the sweeper that owns it, so returning the lane un-reset
    /// would hand the next connection this connection's reservoir state.
    /// A withheld lane is unreachable anyway — with the sweeper dead,
    /// `stream` on it could only error — so capacity is not lost where it
    /// could have been used.
    pub(crate) fn release_lane(&self, lane: usize) {
        let model = self.lane_model[lane].load(Ordering::Relaxed);
        if self.submit(FrontJob::Reset {
            model,
            lane,
            reply: None,
        }) {
            self.lane_model[lane].store(BASE_MODEL, Ordering::Relaxed);
            self.free_lanes.lock().unwrap().push(lane);
        }
    }

    /// Per-model lane occupancy: `(model, lanes bound)` over the lanes
    /// currently handed out, sorted by model id ([`BASE_MODEL`] rows
    /// count base-bound lanes). `info`'s per-model accounting.
    pub fn lane_counts_by_model(&self) -> Vec<(ModelId, usize)> {
        let free = self.free_lanes.lock().unwrap().clone();
        let mut counts: Vec<(ModelId, usize)> = Vec::new();
        for lane in 0..STREAM_LANES {
            if free.contains(&lane) {
                continue;
            }
            let m = self.lane_model[lane].load(Ordering::Relaxed);
            match counts.iter_mut().find(|(id, _)| *id == m) {
                Some((_, c)) => *c += 1,
                None => counts.push((m, 1)),
            }
        }
        counts.sort_unstable();
        counts
    }

    /// The core this front's sweeper thread is pinned to (`None` =
    /// unpinned: pinning off, or `sched_setaffinity` failed).
    pub fn pinned_core(&self) -> Option<usize> {
        match self.pinned_core.load(Ordering::Relaxed) {
            usize::MAX => None,
            c => Some(c),
        }
    }

    /// Current queued-job count (metrics; exported via `info`; the
    /// sharded front's least-loaded predict deal reads it per shard).
    /// Lock-free: reads the mirror the queue operations maintain, so
    /// polling every shard per predict never touches the queue mutex.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Total sweep rounds drained so far (metrics; exported via `info`).
    pub fn sweep_count(&self) -> u64 {
        self.sweeps.load(Ordering::Relaxed)
    }

    /// Sweep panics contained so far (each one poisoned the lanes of its
    /// batch and restarted the sweeper in place).
    pub fn sweeper_panics(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Jobs shed at admission with the typed `overloaded` error so far
    /// (metrics; exported via `info`).
    pub fn jobs_shed(&self) -> u64 {
        self.jobs_shed.load(Ordering::Relaxed)
    }

    /// Jobs refused with the typed `deadline_exceeded` error so far
    /// (metrics; exported via `info`).
    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses.load(Ordering::Relaxed)
    }

    /// Streaming lanes currently handed out — the occupancy signal the
    /// rebalance policy and the migration target choice read.
    pub fn lanes_in_use(&self) -> usize {
        STREAM_LANES - self.free_lanes.lock().unwrap().len()
    }

    /// Distinct pooled predict engines built so far (flat once warm:
    /// chunk-size reuse means coalesced predicts stop paying the
    /// parameter-downcast + plane-allocation cost per chunk).
    pub fn predict_engines_built(&self) -> u64 {
        self.engines_built.load(Ordering::Relaxed)
    }

    /// The configured hold-off window in µs (metrics; `info`).
    pub fn holdoff_us(&self) -> u64 {
        self.holdoff.as_micros() as u64
    }

    /// Switch this front between the fixed window (`false`, default)
    /// and autotuned mode (`true`). Flipped once at server start by
    /// `serve_on_opts`; safe to flip live (the sweeper re-reads the
    /// mode every drain round).
    pub fn set_holdoff_auto(&self, on: bool) {
        self.holdoff_auto.store(on, Ordering::Relaxed);
    }

    /// Fold one job arrival into the inter-arrival EWMA (autotuned mode
    /// only — the fixed-window hot path never takes this branch).
    fn record_arrival(&self) {
        let now_us = self.epoch.elapsed().as_micros() as u64;
        let last = self.last_arrival_us.swap(now_us, Ordering::Relaxed);
        if last == u64::MAX {
            return; // first arrival ever: no gap to observe yet
        }
        let gap = now_us.saturating_sub(last) as f64;
        let old = f64::from_bits(self.arrival_ewma_us.load(Ordering::Relaxed));
        let new = if old == 0.0 {
            gap
        } else {
            ARRIVAL_EWMA_ALPHA * gap + (1.0 - ARRIVAL_EWMA_ALPHA) * old
        };
        self.arrival_ewma_us.store(new.to_bits(), Ordering::Relaxed);
    }

    /// The coalescing window the sweeper will use for its NEXT shallow-
    /// queue wait. Fixed mode: the configured window, verbatim.
    /// Autotuned mode sizes the window to the observed feed rate —
    /// roughly four expected inter-arrival gaps (enough to coalesce a
    /// small batch), never above the configured `--holdoff-us` cap, and
    /// ZERO when the shard looks idle (no arrival yet, or the time
    /// since the last arrival already exceeds the cap), so light
    /// traffic converges to zero added latency.
    fn effective_holdoff(&self) -> Duration {
        if !self.holdoff_auto.load(Ordering::Relaxed) {
            return self.holdoff;
        }
        let last = self.last_arrival_us.load(Ordering::Relaxed);
        if last == u64::MAX {
            return Duration::ZERO;
        }
        let cap_us = self.holdoff.as_micros() as u64;
        let now_us = self.epoch.elapsed().as_micros() as u64;
        let since = now_us.saturating_sub(last);
        if since >= cap_us {
            return Duration::ZERO; // gone idle: drain immediately
        }
        let ewma = f64::from_bits(self.arrival_ewma_us.load(Ordering::Relaxed));
        if ewma == 0.0 {
            // a single arrival, no gap observed: keep the full cap
            return self.holdoff;
        }
        if ewma >= cap_us as f64 {
            return Duration::ZERO; // arrivals sparser than the cap
        }
        Duration::from_micros(((4.0 * ewma) as u64).min(cap_us))
    }

    /// [`Self::effective_holdoff`] in µs (metrics; `info`'s
    /// `holdoff_effective_us`). Equals `holdoff_us` in fixed mode.
    pub fn holdoff_effective_us(&self) -> u64 {
        self.effective_holdoff().as_micros() as u64
    }

    /// The model this front serves.
    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }

    /// The multi-tenant registry this front serves from (`None` =
    /// legacy single-model front).
    pub fn registry(&self) -> Option<&Arc<ModelRegistry>> {
        self.registry.as_ref()
    }

    /// Stateless prediction through the batch queue. Falls back to a
    /// direct (bit-identical, same-precision) computation if the sweeper
    /// is gone. The input is shared with the queue via `Arc`, not
    /// cloned.
    pub fn predict(&self, input: Vec<f64>) -> Vec<f64> {
        let input = Arc::new(input);
        let (tx, rx) = mpsc::channel();
        if self.submit(FrontJob::Predict {
            model: BASE_MODEL,
            input: Arc::clone(&input),
            reply: ReplySender::Chan(tx),
        }) {
            // a dying sweeper drops stranded jobs, so this cannot hang
            if let Ok(Reply::Vals(out)) = rx.recv() {
                return out;
            }
        }
        self.model.predict(&input)
    }

    /// [`Self::predict`] under a client deadline: a shed or expired job
    /// answers the typed error instead of the dead-sweeper fallback —
    /// overload protection must degrade with a bounded typed response,
    /// not silently absorb the queue's work onto the caller thread.
    pub fn predict_deadline(
        &self,
        input: Vec<f64>,
        deadline: Option<Instant>,
    ) -> Result<Vec<f64>> {
        let input = Arc::new(input);
        let (tx, rx) = mpsc::channel();
        if self.submit_predict_deadline(
            Arc::clone(&input),
            ReplySender::Chan(tx),
            deadline,
        ) {
            match rx.recv() {
                Ok(Reply::Vals(out)) => return Ok(out),
                Ok(Reply::Err(code)) => {
                    return Err(super::wire::coded_error(code))
                }
                _ => {}
            }
        }
        // dead sweeper: the direct bit-identical fallback, still honoring
        // an already-expired deadline with the typed refusal
        if deadline.is_some_and(|d| Instant::now() >= d) {
            self.deadline_misses.fetch_add(1, Ordering::Relaxed);
            return Err(super::wire::coded_error("deadline_exceeded"));
        }
        Ok(self.model.predict(&input))
    }

    /// [`Self::predict_deadline`] against a registered tenant model —
    /// the wire layer's blocking model-addressed predict. The
    /// dead-sweeper fallback resolves the tenant through the registry
    /// directly (typed `unknown_model` when it isn't there).
    pub fn predict_deadline_model(
        &self,
        model: ModelId,
        input: Vec<f64>,
        deadline: Option<Instant>,
    ) -> Result<Vec<f64>> {
        if model == BASE_MODEL {
            return self.predict_deadline(input, deadline);
        }
        let input = Arc::new(input);
        let (tx, rx) = mpsc::channel();
        if self.submit_predict_model(
            model,
            Arc::clone(&input),
            ReplySender::Chan(tx),
            deadline,
        ) {
            match rx.recv() {
                Ok(Reply::Vals(out)) => return Ok(out),
                Ok(Reply::Err(code)) => {
                    return Err(super::wire::coded_error(code))
                }
                _ => {}
            }
        }
        if deadline.is_some_and(|d| Instant::now() >= d) {
            self.deadline_misses.fetch_add(1, Ordering::Relaxed);
            return Err(super::wire::coded_error("deadline_exceeded"));
        }
        let m = self
            .registry
            .as_ref()
            .and_then(|r| r.get(model))
            .ok_or_else(|| super::wire::coded_error("unknown_model"))?;
        Ok(m.predict(&input))
    }

    /// Enqueue a stateless prediction and return the reply channel
    /// without blocking — the fan-out form ([`super::ShardedFront`] and
    /// the benches submit whole batches before collecting). `None` when
    /// the sweeper is gone; callers then use [`Model::predict`] directly.
    pub fn predict_async(
        &self,
        input: Vec<f64>,
    ) -> Option<mpsc::Receiver<Reply>> {
        self.predict_async_model(BASE_MODEL, input)
    }

    /// [`Self::predict_async`] against a registered tenant model — the
    /// multi-tenant fan-out form (and the `tenant128` bench's driver).
    /// An unknown model answers the typed `unknown_model` error on the
    /// reply channel, not here: the registry is consulted by the sweeper
    /// so submission stays lock-free.
    pub fn predict_async_model(
        &self,
        model: ModelId,
        input: Vec<f64>,
    ) -> Option<mpsc::Receiver<Reply>> {
        let (tx, rx) = mpsc::channel();
        if self.submit(FrontJob::Predict {
            model,
            input: Arc::new(input),
            reply: ReplySender::Chan(tx),
        }) {
            Some(rx)
        } else {
            None
        }
    }

    /// Enqueue a stateless prediction with an arbitrary reply sink (the
    /// event loop passes an [`EventReply`]). Returns `false` when the
    /// sweeper is gone — but an `Event` reply still delivers its
    /// `Dropped` completion either way, so event-loop callers need not
    /// branch on the return value.
    pub(crate) fn submit_predict(
        &self,
        input: Arc<Vec<f64>>,
        reply: ReplySender,
    ) -> bool {
        self.submit_predict_model(BASE_MODEL, input, reply, None)
    }

    /// [`Self::submit_predict`] with a client deadline: expired (at
    /// admission or when the sweeper reaches the job) answers the typed
    /// `deadline_exceeded` code instead of running.
    pub(crate) fn submit_predict_deadline(
        &self,
        input: Arc<Vec<f64>>,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        self.submit_predict_model(BASE_MODEL, input, reply, deadline)
    }

    /// The full stateless-predict form: model-addressed and deadlined —
    /// the wire layer routes tenant predicts through here.
    pub(crate) fn submit_predict_model(
        &self,
        model: ModelId,
        input: Arc<Vec<f64>>,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        self.submit_with_deadline(
            FrontJob::Predict {
                model,
                input,
                reply,
            },
            deadline,
        )
    }

    /// Enqueue streaming step(s) on a hub lane with an arbitrary reply
    /// sink (see [`Self::submit_predict`] on the return value).
    ///
    /// A multi-output model cannot stream — the hub's masked sweep
    /// asserts `D_out = 1` ON THE SWEEPER THREAD, where a panic kills
    /// the whole shard. Refusing here (every stream path funnels through
    /// this method) keeps the invariant next to the code that asserts
    /// it; the wire layer rejects earlier with a friendlier message.
    pub(crate) fn submit_stream(
        &self,
        lane: usize,
        input: Vec<f64>,
        reply: ReplySender,
    ) -> bool {
        self.submit_stream_deadline(lane, input, reply, None)
    }

    /// [`Self::submit_stream`] with a client deadline (see
    /// [`Self::submit_predict_deadline`]).
    pub(crate) fn submit_stream_deadline(
        &self,
        lane: usize,
        input: Vec<f64>,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        let model = self.lane_model[lane].load(Ordering::Relaxed);
        // minted tenant models are always single-output, so only a
        // base-bound lane can hit the multi-output refusal
        if model == BASE_MODEL && self.model.readout.w.cols() != 1 {
            return false;
        }
        self.submit_with_deadline(
            FrontJob::Stream {
                model,
                lane,
                input,
                reply,
            },
            deadline,
        )
    }

    /// Enqueue online training step(s) on a hub lane with an arbitrary
    /// reply sink. Refused (like [`Self::submit_stream`]) on multi-output
    /// models — the trainer fits a single-output readout — and on
    /// mismatched input/target lengths; the wire layer rejects both
    /// earlier with friendlier messages.
    pub(crate) fn submit_train(
        &self,
        lane: usize,
        input: Vec<f64>,
        target: Vec<f64>,
        reply: ReplySender,
    ) -> bool {
        self.submit_train_deadline(lane, input, target, reply, None)
    }

    /// [`Self::submit_train`] with a client deadline (see
    /// [`Self::submit_predict_deadline`]).
    pub(crate) fn submit_train_deadline(
        &self,
        lane: usize,
        input: Vec<f64>,
        target: Vec<f64>,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        let model = self.lane_model[lane].load(Ordering::Relaxed);
        if (model == BASE_MODEL && self.model.readout.w.cols() != 1)
            || input.len() != target.len()
        {
            return false;
        }
        self.submit_with_deadline(
            FrontJob::Train {
                model,
                lane,
                input,
                target,
                reply,
            },
            deadline,
        )
    }

    /// Enqueue a lane commit (ridge solve + readout hot-swap) with an
    /// arbitrary reply sink.
    pub(crate) fn submit_commit(
        &self,
        lane: usize,
        alpha: f64,
        reply: ReplySender,
    ) -> bool {
        self.submit_commit_deadline(lane, alpha, reply, None)
    }

    /// [`Self::submit_commit`] with a client deadline (see
    /// [`Self::submit_predict_deadline`]).
    pub(crate) fn submit_commit_deadline(
        &self,
        lane: usize,
        alpha: f64,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        let model = self.lane_model[lane].load(Ordering::Relaxed);
        self.submit_with_deadline(
            FrontJob::Commit {
                model,
                lane,
                alpha,
                reply,
            },
            deadline,
        )
    }

    /// Enqueue a rollback to a retained committed-readout version with an
    /// arbitrary reply sink.
    pub(crate) fn submit_rollback(
        &self,
        lane: usize,
        version: u64,
        reply: ReplySender,
    ) -> bool {
        self.submit_rollback_deadline(lane, version, reply, None)
    }

    /// [`Self::submit_rollback`] with a client deadline (see
    /// [`Self::submit_predict_deadline`]).
    pub(crate) fn submit_rollback_deadline(
        &self,
        lane: usize,
        version: u64,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        let model = self.lane_model[lane].load(Ordering::Relaxed);
        self.submit_with_deadline(
            FrontJob::Rollback {
                model,
                lane,
                version,
                reply,
            },
            deadline,
        )
    }

    /// Enqueue a lane checkpoint with an arbitrary reply sink.
    pub(crate) fn submit_checkpoint(&self, lane: usize, reply: ReplySender) -> bool {
        self.submit_checkpoint_deadline(lane, reply, None)
    }

    /// [`Self::submit_checkpoint`] with a client deadline (see
    /// [`Self::submit_predict_deadline`]).
    pub(crate) fn submit_checkpoint_deadline(
        &self,
        lane: usize,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        let model = self.lane_model[lane].load(Ordering::Relaxed);
        self.submit_with_deadline(
            FrontJob::Checkpoint { model, lane, reply },
            deadline,
        )
    }

    /// Enqueue a lane restore with an arbitrary reply sink. Refused
    /// (like [`Self::submit_stream`]) on multi-output models — snapshots
    /// describe single-output streaming lanes.
    pub(crate) fn submit_restore(
        &self,
        lane: usize,
        snap: Box<LaneSnapshot>,
        reply: ReplySender,
    ) -> bool {
        self.submit_restore_deadline(lane, snap, reply, None)
    }

    /// [`Self::submit_restore`] with a client deadline (see
    /// [`Self::submit_predict_deadline`]).
    pub(crate) fn submit_restore_deadline(
        &self,
        lane: usize,
        snap: Box<LaneSnapshot>,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        let model = self.lane_model[lane].load(Ordering::Relaxed);
        if model == BASE_MODEL && self.model.readout.w.cols() != 1 {
            return false;
        }
        self.submit_with_deadline(
            FrontJob::Restore {
                model,
                lane,
                snap,
                reply,
            },
            deadline,
        )
    }

    /// Enqueue a client-visible lane reset with an arbitrary reply sink
    /// (answered with an empty vec; see [`Self::submit_predict`] on the
    /// return value).
    pub(crate) fn submit_reset(&self, lane: usize, reply: ReplySender) -> bool {
        self.submit_reset_deadline(lane, reply, None)
    }

    /// [`Self::submit_reset`] with a client deadline (see
    /// [`Self::submit_predict_deadline`]).
    pub(crate) fn submit_reset_deadline(
        &self,
        lane: usize,
        reply: ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        let model = self.lane_model[lane].load(Ordering::Relaxed);
        self.submit_with_deadline(
            FrontJob::Reset {
                model,
                lane,
                reply: Some(reply),
            },
            deadline,
        )
    }

    /// Block on a channel reply and map the three outcomes: values pass
    /// through, typed error codes become the shared wire error, and a
    /// dropped sender (dead sweeper / contained panic unwound the job)
    /// becomes the deterministic "unavailable" error.
    fn recv_vals(rx: &mpsc::Receiver<Reply>) -> Result<Vec<f64>> {
        match rx.recv() {
            Ok(Reply::Vals(v)) => Ok(v),
            Ok(Reply::Err(code)) => Err(super::wire::coded_error(code)),
            _ => Err(super::wire::unavailable_error()),
        }
    }

    /// Streaming step(s) on a hub lane (no fallback: the state lives in
    /// the hub, so a dead sweeper is a hard error).
    pub fn stream(&self, lane: usize, input: Vec<f64>) -> Result<Vec<f64>> {
        self.stream_deadline(lane, input, None)
    }

    /// [`Self::stream`] under a client deadline: expired answers the
    /// typed `deadline_exceeded` error without advancing the lane.
    pub fn stream_deadline(
        &self,
        lane: usize,
        input: Vec<f64>,
        deadline: Option<Instant>,
    ) -> Result<Vec<f64>> {
        // distinguish "the op is unsupported" from "the front is dead" —
        // submit_stream refuses both with one bool (tenant lanes are
        // always single-output, so only base-bound lanes need the guard)
        if self.lane_model[lane].load(Ordering::Relaxed) == BASE_MODEL {
            super::wire::guard_streamable(&self.model)?;
        }
        let (tx, rx) = mpsc::channel();
        if !self.submit_stream_deadline(lane, input, ReplySender::Chan(tx), deadline)
        {
            return Err(super::wire::unavailable_error());
        }
        Self::recv_vals(&rx)
    }

    /// Synchronous online training step(s) on a hub lane: advance the
    /// lane exactly like [`Self::stream`] would AND stream each step's
    /// `(features, target)` pair into the lane's Gram accumulator on the
    /// sweeper thread. Returns the lane's total accumulated row count.
    pub fn train(&self, lane: usize, input: Vec<f64>, target: Vec<f64>) -> Result<u64> {
        self.train_deadline(lane, input, target, None)
    }

    /// [`Self::train`] under a client deadline (see
    /// [`Self::stream_deadline`]).
    pub fn train_deadline(
        &self,
        lane: usize,
        input: Vec<f64>,
        target: Vec<f64>,
        deadline: Option<Instant>,
    ) -> Result<u64> {
        if self.lane_model[lane].load(Ordering::Relaxed) == BASE_MODEL {
            super::wire::guard_streamable(&self.model)?;
        }
        anyhow::ensure!(
            input.len() == target.len(),
            "train input/target length mismatch ({} vs {})",
            input.len(),
            target.len()
        );
        let (tx, rx) = mpsc::channel();
        if !self.submit_train_deadline(
            lane,
            input,
            target,
            ReplySender::Chan(tx),
            deadline,
        ) {
            return Err(super::wire::unavailable_error());
        }
        let v = Self::recv_vals(&rx)?;
        Ok(v.first().copied().unwrap_or(0.0) as u64)
    }

    /// Synchronous lane commit: solve the accumulated ridge system at the
    /// hub's precision and atomically hot-swap this lane's readout —
    /// subsequent [`Self::stream`] calls on the lane use it. Returns the
    /// newly retained readout's version id (monotonic per lane).
    pub fn commit(&self, lane: usize, alpha: f64) -> Result<u64> {
        self.commit_deadline(lane, alpha, None)
    }

    /// [`Self::commit`] under a client deadline (see
    /// [`Self::stream_deadline`]).
    pub fn commit_deadline(
        &self,
        lane: usize,
        alpha: f64,
        deadline: Option<Instant>,
    ) -> Result<u64> {
        let (tx, rx) = mpsc::channel();
        if !self.submit_commit_deadline(lane, alpha, ReplySender::Chan(tx), deadline)
        {
            return Err(super::wire::unavailable_error());
        }
        let v = Self::recv_vals(&rx)?;
        Ok(v.first().copied().unwrap_or(0.0) as u64)
    }

    /// Synchronous rollback: atomically reinstall a retained committed
    /// readout version (0 = base model readout) without dropping
    /// accumulated training rows. Returns the now-active version id.
    pub fn rollback(&self, lane: usize, version: u64) -> Result<u64> {
        self.rollback_deadline(lane, version, None)
    }

    /// [`Self::rollback`] under a client deadline (see
    /// [`Self::stream_deadline`]).
    pub fn rollback_deadline(
        &self,
        lane: usize,
        version: u64,
        deadline: Option<Instant>,
    ) -> Result<u64> {
        let (tx, rx) = mpsc::channel();
        if !self.submit_rollback_deadline(
            lane,
            version,
            ReplySender::Chan(tx),
            deadline,
        ) {
            return Err(super::wire::unavailable_error());
        }
        let v = Self::recv_vals(&rx)?;
        Ok(v.first().copied().unwrap_or(0.0) as u64)
    }

    /// Synchronous lane checkpoint: the lane's full portable value,
    /// bit-exact at both precisions.
    pub fn checkpoint(&self, lane: usize) -> Result<LaneSnapshot> {
        self.checkpoint_deadline(lane, None)
    }

    /// [`Self::checkpoint`] under a client deadline (see
    /// [`Self::stream_deadline`]).
    pub fn checkpoint_deadline(
        &self,
        lane: usize,
        deadline: Option<Instant>,
    ) -> Result<LaneSnapshot> {
        let (tx, rx) = mpsc::channel();
        if !self.submit_checkpoint_deadline(lane, ReplySender::Chan(tx), deadline) {
            return Err(super::wire::unavailable_error());
        }
        match rx.recv() {
            Ok(Reply::Snap(s)) => Ok(*s),
            Ok(Reply::Err(code)) => Err(super::wire::coded_error(code)),
            _ => Err(super::wire::unavailable_error()),
        }
    }

    /// Synchronous lane restore: validate and atomically install a
    /// snapshot (clearing any poison quarantine). Returns the restored
    /// active version id.
    pub fn restore(&self, lane: usize, snap: LaneSnapshot) -> Result<u64> {
        self.restore_deadline(lane, snap, None)
    }

    /// [`Self::restore`] under a client deadline (see
    /// [`Self::stream_deadline`]).
    pub fn restore_deadline(
        &self,
        lane: usize,
        snap: LaneSnapshot,
        deadline: Option<Instant>,
    ) -> Result<u64> {
        let (tx, rx) = mpsc::channel();
        if !self.submit_restore_deadline(
            lane,
            Box::new(snap),
            ReplySender::Chan(tx),
            deadline,
        ) {
            return Err(super::wire::unavailable_error());
        }
        let v = Self::recv_vals(&rx)?;
        Ok(v.first().copied().unwrap_or(0.0) as u64)
    }

    /// Synchronous client-visible lane reset.
    pub fn reset(&self, lane: usize) -> Result<()> {
        self.reset_deadline(lane, None)
    }

    /// [`Self::reset`] under a client deadline (see
    /// [`Self::stream_deadline`]).
    pub fn reset_deadline(
        &self,
        lane: usize,
        deadline: Option<Instant>,
    ) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        if !self.submit_reset_deadline(lane, ReplySender::Chan(tx), deadline) {
            return Err(super::wire::unavailable_error());
        }
        match rx.recv() {
            Ok(Reply::Err(code)) => Err(super::wire::coded_error(code)),
            Ok(_) => Ok(()),
            Err(_) => Err(super::wire::unavailable_error()),
        }
    }

    fn sweeper_loop(&self) {
        // persistent streaming hubs — the base hub plus lazily built
        // per-tenant hubs, one lane per connection, each at its model's
        // precision — and the pooled stateless predict engines (all
        // owned by this thread: no locks on the hot path)
        let mut hubs = HubSet::new(
            &self.model,
            self.registry.clone(),
            self.trainer_budget,
            // --pin-cores: this thread is affined to one core for good;
            // fault the planes in from here so first-touch homes them on
            // this core's NUMA node
            self.pinned_core().is_some(),
        );
        let mut pool =
            EnginePool::new(Arc::clone(&self.model), self.registry.clone());
        loop {
            let drained = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if !st.jobs.is_empty() {
                        // shallow queue: hold off briefly so concurrent
                        // requests coalesce into one sweep; deep queue or
                        // shutdown: drain now (in autotuned mode the
                        // window tracks the observed feed rate — read
                        // once per round so one wait uses one window)
                        let holdoff = self.effective_holdoff();
                        if !holdoff.is_zero()
                            && st.jobs.len() < HOLDOFF_DRAIN_DEPTH
                            && !st.shutdown
                        {
                            let start = Instant::now();
                            while st.jobs.len() < HOLDOFF_DRAIN_DEPTH
                                && !st.shutdown
                            {
                                match holdoff.checked_sub(start.elapsed()) {
                                    None => break,
                                    Some(left) => {
                                        let (guard, _) = self
                                            .cv
                                            .wait_timeout(st, left)
                                            .unwrap();
                                        st = guard;
                                    }
                                }
                            }
                        }
                        let jobs = std::mem::take(&mut st.jobs);
                        self.depth.store(0, Ordering::Relaxed);
                        break jobs;
                    }
                    if st.shutdown {
                        return;
                    }
                    st = self.cv.wait(st).unwrap();
                }
            };
            self.sweeps.fetch_add(1, Ordering::Relaxed);
            // Panic containment: one drained batch runs under
            // catch_unwind, so an engine assert (or an injected fault)
            // cannot take the shard down. The lanes this batch touches
            // are recorded FIRST — they are the only lanes whose hub
            // state can be mid-update when the unwind happens — and are
            // quarantined (poisoned) on panic, while every untouched
            // lane keeps bit-identical state and the sweeper restarts
            // in place on the same hub. Replies the unwound batch never
            // sent are dropped, which both transports surface as the
            // deterministic "unavailable" error.
            let touched: Vec<(ModelId, usize)> =
                drained.iter().filter_map(|j| j.job.lane()).collect();
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || self.process(&mut hubs, &mut pool, drained),
            ));
            if let Err(_payload) = res {
                #[cfg(any(test, feature = "fault-inject"))]
                if _payload.is::<super::fault::SweeperKill>() {
                    // injected hard kill: escalate to the outer handler
                    // (permanent front death — the legacy failure mode
                    // the chaos suite migrates away from)
                    std::panic::resume_unwind(_payload);
                }
                let n_poisoned = touched.len();
                for (model, lane) in touched {
                    hubs.poison(model, lane);
                }
                // pooled predict engines may be mid-update too; rebuild
                // them (cheap, lazily refilled — the hub lanes are what
                // must survive)
                pool = EnginePool::new(
                    Arc::clone(&self.model),
                    self.registry.clone(),
                );
                self.panics.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "lr-batch-sweeper: sweep panicked; quarantined \
                     {n_poisoned} lane job(s), sweeper restarted in place"
                );
            }
            // deleted tenants: drop their cached hubs/engines once no
            // lane still binds them (no-ops on the zero-tenant path)
            hubs.prune(&self.lane_model);
            pool.prune();
        }
    }

    /// Drain one batch of jobs: predicts coalesce into stateless sweeps;
    /// stream/reset jobs are grouped into rounds that preserve per-lane
    /// submission order (lanes are independent, so cross-lane reordering
    /// is unobservable). Each round is partitioned by the model its
    /// jobs are stamped with and served with ONE masked sweep per model
    /// group — with zero tenants every job lands in the single base
    /// group, which is bit-identical to the pre-registry behavior.
    fn process(
        &self,
        hubs: &mut HubSet,
        pool: &mut EnginePool,
        drained: Vec<QueuedJob>,
    ) {
        let mut predicts: Vec<(ModelId, Arc<Vec<f64>>, ReplySender)> = Vec::new();
        let mut round: Vec<(ModelId, usize, Vec<f64>, ReplySender)> = Vec::new();
        let mut in_round = [false; STREAM_LANES];

        let flush_round =
            |round: &mut Vec<(ModelId, usize, Vec<f64>, ReplySender)>,
             in_round: &mut [bool; STREAM_LANES],
             hubs: &mut HubSet| {
                if round.is_empty() {
                    return;
                }
                // partition by model, preserving submission order within
                // each group: a lane is bound to exactly one model at a
                // time, so per-lane order survives and cross-model
                // reordering is unobservable
                let mut groups: Vec<(
                    ModelId,
                    Vec<(usize, Vec<f64>, ReplySender)>,
                )> = Vec::new();
                for (model, lane, input, reply) in round.drain(..) {
                    match groups.iter_mut().find(|(m, _)| *m == model) {
                        Some((_, g)) => g.push((lane, input, reply)),
                        None => groups.push((model, vec![(lane, input, reply)])),
                    }
                }
                for (model, group) in groups {
                    let Some(hub) = hubs.hub_for(model) else {
                        for (_, _, reply) in group {
                            reply.send(Reply::Err("unknown_model"));
                        }
                        continue;
                    };
                    let reqs: Vec<(usize, &[f64])> = group
                        .iter()
                        .map(|(lane, input, _)| (*lane, input.as_slice()))
                        .collect();
                    let outs = hub.sweep_streams(&reqs);
                    for ((_, _, reply), out) in group.into_iter().zip(outs) {
                        reply.send(Reply::Vals(out));
                    }
                }
                in_round.fill(false);
            };

        for QueuedJob { job, deadline } in drained {
            // a job whose deadline passed while queued is refused BEFORE
            // touching any lane — an expired op never advances state, so
            // the client's retry continues the stream bit-identically
            if deadline.is_some_and(|d| Instant::now() >= d) {
                self.deadline_misses.fetch_add(1, Ordering::Relaxed);
                job.refuse("deadline_exceeded");
                continue;
            }
            match job {
                FrontJob::Predict {
                    model,
                    input,
                    reply,
                } => predicts.push((model, input, reply)),
                FrontJob::Stream {
                    model,
                    lane,
                    input,
                    reply,
                } => {
                    super::fault::sweeper_job_tick();
                    if in_round[lane] {
                        // second request for a lane: close the round first
                        // so per-lane order is preserved
                        flush_round(&mut round, &mut in_round, hubs);
                    }
                    match hubs.hub_for(model) {
                        None => {
                            reply.send(Reply::Err("unknown_model"));
                            continue;
                        }
                        Some(hub) if hub.poisoned(lane) => {
                            reply.send(Reply::Err("lane_poisoned"));
                            continue;
                        }
                        Some(_) => {}
                    }
                    in_round[lane] = true;
                    round.push((model, lane, input, reply));
                }
                FrontJob::Train {
                    model,
                    lane,
                    input,
                    target,
                    reply,
                } => {
                    super::fault::sweeper_job_tick();
                    // stateful like Stream: close any open round touching
                    // this lane first so per-lane order is preserved
                    if in_round[lane] {
                        flush_round(&mut round, &mut in_round, hubs);
                    }
                    let Some(hub) = hubs.hub_for(model) else {
                        reply.send(Reply::Err("unknown_model"));
                        continue;
                    };
                    if hub.poisoned(lane) {
                        reply.send(Reply::Err("lane_poisoned"));
                        continue;
                    }
                    reply.send(hub.train(lane, &input, &target));
                }
                FrontJob::Commit {
                    model,
                    lane,
                    alpha,
                    reply,
                } => {
                    super::fault::sweeper_job_tick();
                    if in_round[lane] {
                        flush_round(&mut round, &mut in_round, hubs);
                    }
                    let Some(hub) = hubs.hub_for(model) else {
                        reply.send(Reply::Err("unknown_model"));
                        continue;
                    };
                    if hub.poisoned(lane) {
                        reply.send(Reply::Err("lane_poisoned"));
                        continue;
                    }
                    reply.send(hub.commit(lane, alpha));
                }
                FrontJob::Rollback {
                    model,
                    lane,
                    version,
                    reply,
                } => {
                    super::fault::sweeper_job_tick();
                    if in_round[lane] {
                        flush_round(&mut round, &mut in_round, hubs);
                    }
                    let Some(hub) = hubs.hub_for(model) else {
                        reply.send(Reply::Err("unknown_model"));
                        continue;
                    };
                    if hub.poisoned(lane) {
                        reply.send(Reply::Err("lane_poisoned"));
                        continue;
                    }
                    reply.send(hub.rollback(lane, version));
                }
                FrontJob::Checkpoint { model, lane, reply } => {
                    super::fault::sweeper_job_tick();
                    // the snapshot must include every op already in this
                    // batch for the lane, so close any open round first
                    if in_round[lane] {
                        flush_round(&mut round, &mut in_round, hubs);
                    }
                    let Some(hub) = hubs.hub_for(model) else {
                        reply.send(Reply::Err("unknown_model"));
                        continue;
                    };
                    if hub.poisoned(lane) {
                        // a poisoned lane's state may be mid-update:
                        // snapshotting it would capture (and later
                        // faithfully restore) corruption
                        reply.send(Reply::Err("lane_poisoned"));
                        continue;
                    }
                    reply.send(hub.checkpoint(lane));
                }
                FrontJob::Restore {
                    model,
                    lane,
                    snap,
                    reply,
                } => {
                    super::fault::sweeper_job_tick();
                    // restore is the recovery op: allowed (and poison-
                    // clearing) on a quarantined lane
                    if in_round[lane] {
                        flush_round(&mut round, &mut in_round, hubs);
                    }
                    match hubs.hub_for(model) {
                        Some(hub) => reply.send(hub.restore(lane, &snap)),
                        None => reply.send(Reply::Err("unknown_model")),
                    }
                }
                FrontJob::Reset { model, lane, reply } => {
                    if in_round[lane] {
                        flush_round(&mut round, &mut in_round, hubs);
                    }
                    // a recycle reset whose hub is already pruned (model
                    // deleted, binding cleared) has no state left to
                    // zero — the hub went away with it
                    if let Some(hub) = hubs.hub_for(model) {
                        hub.reset_lane(lane);
                    }
                    if let Some(tx) = reply {
                        tx.send(Reply::Vals(Vec::new()));
                    }
                }
            }
        }
        flush_round(&mut round, &mut in_round, hubs);

        // predicts: stateless — partitioned by model (zero tenants ⇒ a
        // single base partition with today's exact chunking), then a
        // pooled, reset, precision-matched engine per (model, width)
        // chunk (reused across rounds: no parameter downcast or plane
        // allocation once a (model, chunk size) has been seen)
        let mut parts: Vec<(ModelId, Vec<(Arc<Vec<f64>>, ReplySender)>)> =
            Vec::new();
        for (model, input, reply) in predicts {
            match parts.iter_mut().find(|(m, _)| *m == model) {
                Some((_, g)) => g.push((input, reply)),
                None => parts.push((model, vec![(input, reply)])),
            }
        }
        for (model, group) in parts {
            // minted tenant models are always single-output; only the
            // base model can carry a general D_out readout
            let d_out = if model == BASE_MODEL {
                self.model.readout.w.cols()
            } else {
                1
            };
            let mut group = group.into_iter();
            loop {
                let chunk: Vec<(Arc<Vec<f64>>, ReplySender)> =
                    group.by_ref().take(MAX_PREDICT_BATCH).collect();
                if chunk.is_empty() {
                    break;
                }
                let k = chunk.len();
                let Some(engine) = pool.get(model, k) else {
                    // the model vanished between submit and sweep
                    for (_, reply) in chunk {
                        reply.send(Reply::Err("unknown_model"));
                    }
                    continue;
                };
                if d_out == 1 {
                    // masked sweep: exhausted lanes freeze, so a short
                    // request never pays for the longest one in its batch
                    let reqs: Vec<(usize, &[f64])> = chunk
                        .iter()
                        .enumerate()
                        .map(|(b, (input, _))| (b, input.as_slice()))
                        .collect();
                    let outs = engine.sweep_streams(&reqs);
                    for ((_, reply), out) in chunk.into_iter().zip(outs) {
                        reply.send(Reply::Vals(out));
                    }
                } else {
                    // general D_out: zero-padded full sweep (padded steps
                    // and the pooled engine's spare bucket lanes are never
                    // read, so outputs are unchanged)
                    let max_len =
                        chunk.iter().map(|(i, _)| i.len()).max().unwrap_or(0);
                    let mut u = Mat::zeros(max_len, engine.lanes());
                    for (b, (input, _)) in chunk.iter().enumerate() {
                        for (t, &v) in input.iter().enumerate() {
                            u[(t, b)] = v;
                        }
                    }
                    let y = engine.run_readout(&u);
                    for (b, (input, reply)) in chunk.into_iter().enumerate() {
                        // ALL d_out columns of this lane, step-major — the
                        // same `[T × D_out]` flattening Model::predict
                        // returns, so multi-output responses carry every
                        // output, not just column 0
                        let mut out = Vec::with_capacity(input.len() * d_out);
                        for t in 0..input.len() {
                            for j in 0..d_out {
                                out.push(y[(t, b * d_out + j)]);
                            }
                        }
                        reply.send(Reply::Vals(out));
                    }
                }
            }
        }
        self.engines_built.store(pool.built(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{make_model, make_model_f32};
    use super::*;
    use crate::tasks::mso::MsoTask;

    #[test]
    fn batched_front_predict_is_bit_identical_to_model_predict() {
        // the batching contract: coalescing must be invisible — same bits
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(2);
        let inputs: Vec<Vec<f64>> = (0..7)
            .map(|i| task.input[i * 10..i * 10 + 35 + i].to_vec())
            .collect();
        // submit all jobs before the sweeper can drain them one by one:
        // hold the queue lock while enqueueing
        let replies: Vec<mpsc::Receiver<Reply>> = {
            let mut st = front.state.lock().unwrap();
            inputs
                .iter()
                .map(|input| {
                    let (tx, rx) = mpsc::channel();
                    st.jobs.push(QueuedJob {
                        job: FrontJob::Predict {
                            model: BASE_MODEL,
                            input: Arc::new(input.clone()),
                            reply: ReplySender::Chan(tx),
                        },
                        deadline: None,
                    });
                    rx
                })
                .collect()
        };
        front.cv.notify_all();
        for (input, rx) in inputs.iter().zip(replies) {
            let batched = match rx.recv().unwrap() {
                Reply::Vals(v) => v,
                other => panic!("expected values, got {other:?}"),
            };
            let sequential = model.predict(input);
            assert_eq!(batched.len(), sequential.len());
            for (a, b) in batched.iter().zip(&sequential) {
                assert!(
                    (a - b).abs() == 0.0,
                    "batched predict must be bit-identical: {a} vs {b}"
                );
            }
        }
        front.shutdown();
    }

    #[test]
    fn hub_lanes_are_isolated_and_match_sequential_streaming() {
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let a = front.acquire_lane().unwrap();
        let b = front.acquire_lane().unwrap();
        assert_ne!(a, b);
        // interleave chunks on two lanes
        let in_a = &task.input[..40];
        let in_b = &task.input[200..230];
        let mut got_a = front.stream(a, in_a[..15].to_vec()).unwrap();
        let mut got_b = front.stream(b, in_b[..7].to_vec()).unwrap();
        got_a.extend(front.stream(a, in_a[15..].to_vec()).unwrap());
        got_b.extend(front.stream(b, in_b[7..].to_vec()).unwrap());
        // reference: each stream alone through the sequential model path
        let reference = |input: &[f64]| model.predict(input);
        for (got, want) in [(got_a, reference(in_a)), (got_b, reference(in_b))] {
            assert_eq!(got.len(), want.len());
            for (x, y) in got.iter().zip(&want) {
                assert!((x - y).abs() < 1e-10, "{x} vs {y}");
            }
        }
        // reset isolates too: lane a resets, lane b keeps its state
        front.reset(a).unwrap();
        let fresh = front.stream(a, in_a[..5].to_vec()).unwrap();
        let ref_a = reference(in_a);
        for (x, y) in fresh.iter().zip(&ref_a[..5]) {
            assert!((x - y).abs() < 1e-10);
        }
        front.release_lane(a);
        front.release_lane(b);
        front.shutdown();
    }

    #[test]
    fn f32_front_predict_matches_f32_model_predict_bitwise() {
        // precision consistency contract: at F32 every path (coalesced
        // sweep, fallback, Model::predict) runs the same f32 lane
        // arithmetic, so responses stay bit-identical across paths
        let model = Arc::new(make_model_f32());
        assert_eq!(model.precision, Precision::F32);
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(2);
        for i in 0..5 {
            let input = task.input[i * 13..i * 13 + 30 + i].to_vec();
            let batched = front.predict(input.clone());
            let direct = model.predict(&input);
            assert_eq!(batched.len(), direct.len());
            for (a, b) in batched.iter().zip(&direct) {
                assert!(
                    (a - b).abs() == 0.0,
                    "f32 batched predict must be bit-identical: {a} vs {b}"
                );
            }
            // and the f32 result is close to (but generally not equal to)
            // the f64 oracle
            let oracle = {
                let u = Mat::from_rows(input.len(), 1, &input);
                let y = model.qesn.run_readout(&u, &model.readout);
                (0..y.rows()).map(|t| y[(t, 0)]).collect::<Vec<f64>>()
            };
            let scale =
                oracle.iter().fold(1.0f64, |m, x| m.max(x.abs()));
            for (a, b) in batched.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-3 * scale, "{a} vs oracle {b}");
            }
        }
        front.shutdown();
    }

    #[test]
    fn f32_hub_streaming_matches_single_lane_f32_reference() {
        let model = Arc::new(make_model_f32());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let lane = front.acquire_lane().unwrap();
        let input = &task.input[..48];
        let mut got = front.stream(lane, input[..17].to_vec()).unwrap();
        got.extend(front.stream(lane, input[17..].to_vec()).unwrap());
        // reference: a private 1-lane f32 engine (the F32 local fallback)
        let mut reference =
            BatchEsn::<f32>::with_precision(model.qesn.clone(), 1);
        let want = reference
            .sweep_streams(&[(0, input)], &model.readout)
            .pop()
            .unwrap();
        assert_eq!(got.len(), want.len());
        for (t, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() == 0.0,
                "f32 hub lane diverged from 1-lane reference at t={t}: {a} vs {b}"
            );
        }
        front.release_lane(lane);
        front.shutdown();
    }

    #[test]
    fn holdoff_front_coalesces_and_counts_sweeps() {
        let model = Arc::new(make_model());
        // generous hold-off so concurrently-submitted jobs coalesce
        let front = BatchFront::start_with_holdoff(Arc::clone(&model), 2_000);
        let task = MsoTask::new(2);
        let inputs: Vec<Vec<f64>> = (0..3)
            .map(|i| task.input[i * 11..i * 11 + 25 + i].to_vec())
            .collect();
        let mut workers = Vec::new();
        for input in inputs {
            let f = Arc::clone(&front);
            let m = Arc::clone(&model);
            workers.push(std::thread::spawn(move || {
                let got = f.predict(input.clone());
                let want = m.predict(&input);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() == 0.0);
                }
            }));
        }
        for w in workers {
            w.join().unwrap();
        }
        // all replies delivered ⇒ at least one sweep ran; with the
        // hold-off they usually coalesce into exactly one
        assert!(front.sweep_count() >= 1);
        assert_eq!(front.queue_depth(), 0);
        front.shutdown();
    }

    #[test]
    fn released_lane_is_withheld_when_sweeper_is_gone() {
        // regression: release_lane used to queue a Reset and push the
        // lane back to the free list even when the sweeper was gone —
        // `submit` returns false, the reset never runs, and the NEXT
        // owner inherits this connection's reservoir state. The fix
        // withholds the un-zeroable lane instead.
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let lane = front.acquire_lane().unwrap();
        // put non-zero state into the lane
        let _ = front.stream(lane, task.input[..10].to_vec()).unwrap();
        // the sweeper shuts down between this connection's release and
        // the next acquire (server shutdown racing connection teardown)
        front.shutdown();
        front.release_lane(lane);
        // the stale lane must never be handed out again: draining the
        // whole free list yields every OTHER lane, and only those
        let mut handed_out = 0;
        while let Some(l) = front.acquire_lane() {
            assert_ne!(l, lane, "stale (un-reset) lane handed back out");
            handed_out += 1;
        }
        assert_eq!(handed_out, STREAM_LANES - 1);
    }

    #[test]
    fn general_d_out_predict_returns_all_output_columns() {
        // regression: the coalesced general-D_out path collected only
        // `y[(t, b*d_out)]` — the first output column per lane — so
        // multi-output models got truncated responses over the wire
        let model = Arc::new(super::super::testutil::make_model_d2());
        let d_out = model.readout.w.cols();
        assert_eq!(d_out, 2);
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        for len in [1usize, 23, 37] {
            let input = task.input[..len].to_vec();
            let got = front.predict(input.clone());
            // T steps × 2 outputs, step-major
            assert_eq!(got.len(), len * d_out, "truncated multi-output reply");
            let u = Mat::from_rows(len, 1, &input);
            let y = model.qesn.run_readout(&u, &model.readout);
            for t in 0..len {
                for j in 0..d_out {
                    let (a, b) = (got[t * d_out + j], y[(t, j)]);
                    assert!(
                        (a - b).abs() == 0.0,
                        "d_out=2 predict diverged at t={t}, j={j}: {a} vs {b}"
                    );
                }
            }
        }
        // the columns carry different trained outputs, so truncation or
        // column aliasing would be visible above
        let probe = front.predict(task.input[..8].to_vec());
        assert!((0..8).any(|t| probe[t * 2] != probe[t * 2 + 1]));
        front.shutdown();
    }

    #[test]
    fn event_reply_delivers_exactly_one_completion() {
        let q = CompletionQueue::new(Box::new(|| {}));
        EventReply::new(7, Arc::clone(&q)).complete(Reply::Vals(vec![1.0]));
        drop(EventReply::new(8, Arc::clone(&q)));
        let drained = q.drain();
        assert_eq!(drained.len(), 2);
        assert!(matches!(
            &drained[0],
            (7, Completion::Done(Reply::Vals(v))) if *v == [1.0]
        ));
        assert!(matches!(&drained[1], (8, Completion::Dropped)));
    }

    #[test]
    fn event_reply_of_a_refused_job_still_completes_as_dropped() {
        // the poll loop registers its pending slot unconditionally; a
        // job refused at submit (shutdown) must still deliver a Dropped
        // completion so the slot resolves into the fallback path
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        front.shutdown();
        let q = CompletionQueue::new(Box::new(|| {}));
        let accepted = front.submit_predict(
            Arc::new(vec![0.1, 0.2]),
            ReplySender::Event(EventReply::new(3, Arc::clone(&q))),
        );
        assert!(!accepted);
        let drained = q.drain();
        assert!(matches!(drained.as_slice(), [(3, Completion::Dropped)]));
    }

    #[test]
    fn event_reply_completes_done_through_the_sweeper_and_wakes() {
        // the full event-reply round trip minus epoll: submit with an
        // Event reply, block on the wake callback, drain the completion,
        // and check the payload is bit-identical to Model::predict
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let input = task.input[..31].to_vec();
        let (wtx, wrx) = mpsc::channel();
        let q = CompletionQueue::new(Box::new(move || {
            let _ = wtx.send(());
        }));
        assert!(front.submit_predict(
            Arc::new(input.clone()),
            ReplySender::Event(EventReply::new(42, Arc::clone(&q))),
        ));
        wrx.recv().expect("sweeper fires the wake callback");
        let drained = q.drain();
        assert_eq!(drained.len(), 1);
        match &drained[0] {
            (42, Completion::Done(Reply::Vals(out))) => {
                let want = model.predict(&input);
                assert_eq!(out.len(), want.len());
                for (a, b) in out.iter().zip(&want) {
                    assert!((a - b).abs() == 0.0);
                }
            }
            other => panic!("expected Done(42), got token {}", other.0),
        }
        front.shutdown();
    }

    #[test]
    fn train_commit_hot_swaps_readout_bit_identically_to_local_fit() {
        // the serving-side training contract at f64: the lane's streamed
        // Gram accumulation + native solve must equal a locally computed
        // fit over the same trajectory bit for bit, and post-commit
        // streams must apply exactly that readout
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let train_in = task.input[..120].to_vec();
        // a target the model readout was NOT fitted to, so the swap is
        // observable
        let target: Vec<f64> =
            train_in.iter().map(|x| 0.5 - 2.0 * x).collect();
        let lane = front.acquire_lane().unwrap();
        // split the training stream across two ops: accumulation must be
        // chunking-invariant
        let r1 = front
            .train(lane, train_in[..47].to_vec(), target[..47].to_vec())
            .unwrap();
        assert_eq!(r1, 47);
        let r2 = front
            .train(lane, train_in[47..].to_vec(), target[47..].to_vec())
            .unwrap();
        assert_eq!(r2, 120);
        front.commit(lane, 1e-8).unwrap();

        // local reference: same trajectory (QBasisEsn run — hub lanes are
        // bit-identical to it), same accumulator, same solve
        let u = Mat::from_rows(train_in.len(), 1, &train_in);
        let x = model.qesn.run(&u);
        let y = Mat::from_rows(target.len(), 1, &target);
        let mut acc = crate::readout::GramAcc::<f64>::new(model.esn.n(), 1);
        acc.push_rows(&x, &y);
        let want_ro = acc.solve_scaled(1e-8, 1.0).unwrap();

        // post-commit stream continues the SAME state and applies the
        // committed readout: reference = continue the run, bias-first
        // ascending-feature accumulation
        let stream_in = task.input[120..160].to_vec();
        let got = front.stream(lane, stream_in.clone()).unwrap();
        let all: Vec<f64> =
            train_in.iter().chain(&stream_in).copied().collect();
        let u_all = Mat::from_rows(all.len(), 1, &all);
        let x_all = model.qesn.run(&u_all);
        for (k, g) in got.iter().enumerate() {
            let want = want_ro.apply_row(x_all.row(120 + k), 0);
            assert!(
                (g - want).abs() == 0.0,
                "post-commit stream diverged at step {k}: {g} vs {want}"
            );
        }
        // and the swap changed predictions vs the model readout
        let model_y: Vec<f64> = {
            let y = model.qesn.run_readout(&u_all, &model.readout);
            (120..160).map(|t| y[(t, 0)]).collect()
        };
        assert!(
            got.iter().zip(&model_y).any(|(a, b)| a != b),
            "committed readout did not change predictions"
        );
        front.release_lane(lane);
        front.shutdown();
    }

    #[test]
    fn commit_without_training_errors_and_reset_clears_training() {
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let lane = front.acquire_lane().unwrap();
        assert!(
            front.commit(lane, 1e-8).is_err(),
            "commit with no trained rows must refuse"
        );
        let _ = front
            .train(lane, task.input[..20].to_vec(), task.input[1..21].to_vec())
            .unwrap();
        front.commit(lane, 1e-8).unwrap();
        // reset returns the lane to a pristine model-readout lane:
        // trainer rows are gone (commit refuses again) and the stream
        // matches the model readout from a zero state
        front.reset(lane).unwrap();
        assert!(front.commit(lane, 1e-8).is_err(), "reset must drop the trainer");
        let got = front.stream(lane, task.input[..10].to_vec()).unwrap();
        let want = model.predict(&task.input[..10]);
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() == 0.0,
                "reset lane must serve the model readout again: {a} vs {b}"
            );
        }
        front.release_lane(lane);
        front.shutdown();
    }

    #[test]
    fn recycled_lane_does_not_inherit_committed_readout() {
        // connection A trains + commits, disconnects; the recycled lane
        // handed to connection B must serve the MODEL readout from zero
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let lane = front.acquire_lane().unwrap();
        let target: Vec<f64> =
            task.input[..30].iter().map(|x| 1.0 - x).collect();
        let _ = front
            .train(lane, task.input[..30].to_vec(), target)
            .unwrap();
        front.commit(lane, 1e-8).unwrap();
        front.release_lane(lane);
        // the freshest free lane is the recycled one (LIFO free list)
        let lane2 = front.acquire_lane().unwrap();
        assert_eq!(lane2, lane, "free list should hand the recycled lane back");
        let got = front.stream(lane2, task.input[..8].to_vec()).unwrap();
        let want = model.predict(&task.input[..8]);
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() == 0.0,
                "recycled lane inherited training: {a} vs {b}"
            );
        }
        front.release_lane(lane2);
        front.shutdown();
    }

    #[test]
    fn f32_train_commit_stream_is_finite_and_swaps() {
        // the f32 hub trains at f32 end-to-end (accumulate + solve at
        // f32): same trajectory on two lanes, one trained+committed, one
        // on the model readout — outputs must differ (swap observable)
        // and stay finite
        let model = Arc::new(make_model_f32());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let trained = front.acquire_lane().unwrap();
        let plain = front.acquire_lane().unwrap();
        let target: Vec<f64> =
            task.input[..100].iter().map(|x| 0.5 - 2.0 * x).collect();
        let rows = front
            .train(trained, task.input[..100].to_vec(), target)
            .unwrap();
        assert_eq!(rows, 100);
        // α well above the f32 noise floor of the Gram diagonal: the MSO
        // trajectory is low-rank, so a too-small ridge would vanish in
        // f32 assembly and leave the system singular
        front.commit(trained, 1e-2).unwrap();
        let _ = front.stream(plain, task.input[..100].to_vec()).unwrap();
        // identical state trajectories from here; different readouts
        let after = front
            .stream(trained, task.input[100..140].to_vec())
            .unwrap();
        let base = front.stream(plain, task.input[100..140].to_vec()).unwrap();
        assert!(after.iter().all(|v| v.is_finite()));
        assert_eq!(after.len(), base.len());
        assert!(after != base, "f32 committed readout unobservable");
        front.release_lane(trained);
        front.release_lane(plain);
        front.shutdown();
    }

    #[test]
    fn predict_engines_are_pooled_across_rounds() {
        // the pool contract: one engine per chunk size, ever — a second
        // round of same-sized predicts reuses the first round's engine
        // (reset on checkout), and responses stay bit-identical
        for model in [Arc::new(make_model()), Arc::new(make_model_f32())] {
            let front = BatchFront::start(Arc::clone(&model));
            let task = MsoTask::new(1);
            let input = task.input[..30].to_vec();
            let first = front.predict(input.clone());
            let second = front.predict(input.clone());
            let third = front.predict(input.clone());
            assert_eq!(first.len(), second.len());
            for (a, b) in first.iter().zip(&second).chain(first.iter().zip(&third)) {
                assert!(
                    (a - b).abs() == 0.0,
                    "pooled engine reuse changed bits: {a} vs {b}"
                );
            }
            // three single-predict rounds, all chunk size 1 → one engine
            assert_eq!(
                front.predict_engines_built(),
                1,
                "chunk-size-1 engine must be built exactly once"
            );
            front.shutdown();
        }
    }

    /// The stable machine-readable code of a typed serving error.
    fn err_code(e: &anyhow::Error) -> &'static str {
        e.downcast_ref::<super::super::wire::WireError>()
            .unwrap_or_else(|| panic!("expected a typed wire error, got {e:#}"))
            .code
    }

    /// Serializes unit tests that arm process-global fault state
    /// (`TARGET_THREAD` is shared, so two armed tests racing would
    /// stomp each other's scope).
    fn fault_guard() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn expired_deadline_refuses_at_admission_without_advancing_state() {
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let lane = front.acquire_lane().unwrap();
        let first = front.stream(lane, task.input[..20].to_vec()).unwrap();
        // non-strict expiry: a deadline of "now" is deterministically
        // late by the time admission checks it
        let err = front
            .stream_deadline(
                lane,
                task.input[20..30].to_vec(),
                Some(Instant::now()),
            )
            .unwrap_err();
        assert_eq!(err_code(&err), "deadline_exceeded");
        assert_eq!(front.deadline_misses(), 1);
        // the refused op never touched the lane: the continuation is
        // bit-identical to an uninterrupted twin
        let rest = front.stream(lane, task.input[20..40].to_vec()).unwrap();
        let reference = model.predict(&task.input[..40]);
        assert_eq!(first, reference[..20]);
        assert_eq!(rest, reference[20..40]);
        // a deadline comfortably in the future is not a refusal
        let ok = front
            .stream_deadline(
                lane,
                task.input[40..50].to_vec(),
                Some(Instant::now() + Duration::from_secs(30)),
            )
            .unwrap();
        assert_eq!(ok, reference[40..50]);
        front.release_lane(lane);
        front.shutdown();
    }

    #[test]
    fn queued_job_past_deadline_is_refused_by_the_sweeper() {
        // the second half of the end-to-end deadline: a job admitted in
        // time whose deadline passes while it waits in the queue is
        // refused when the sweeper reaches it, typed, state untouched
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let (tx, rx) = mpsc::channel();
        {
            let mut st = front.state.lock().unwrap();
            st.jobs.push(QueuedJob {
                job: FrontJob::Stream {
                    model: BASE_MODEL,
                    lane: 0,
                    input: vec![0.1; 4],
                    reply: ReplySender::Chan(tx),
                },
                // already expired when the sweeper drains it
                deadline: Some(Instant::now()),
            });
        }
        front.cv.notify_all();
        assert_eq!(rx.recv().unwrap(), Reply::Err("deadline_exceeded"));
        assert_eq!(front.deadline_misses(), 1);
        // the lane never advanced: a fresh stream starts from zero state
        let lane_zero_probe = front.stream(0, vec![0.1; 4]).unwrap();
        assert_eq!(lane_zero_probe, model.predict(&[0.1; 4]));
        front.shutdown();
    }

    #[test]
    fn forced_admission_depth_sheds_typed_overloaded_and_recovers() {
        use super::super::fault;
        let _guard = fault_guard();
        let model = Arc::new(make_model());
        // dedicated sweeper name: the admission override is scoped to
        // it, so parallel tests' fronts never shed
        let front = BatchFront::start_configured(
            Arc::clone(&model),
            0,
            "lr-admit-unit-sweeper".into(),
            usize::MAX,
        );
        let task = MsoTask::new(1);
        let lane = front.acquire_lane().unwrap();
        let first = front.stream(lane, task.input[..20].to_vec()).unwrap();
        fault::target_sweeper_thread("lr-admit-unit-sweeper");
        fault::force_admit_depth(0);
        let err = front
            .stream(lane, task.input[20..30].to_vec())
            .unwrap_err();
        assert_eq!(err_code(&err), "overloaded");
        assert!(front.jobs_shed() >= 1);
        // lane release under a shed queue must still work: the internal
        // recycling reset bypasses admission (otherwise the next owner
        // would inherit this lane's state)
        let spare = front.acquire_lane().unwrap();
        front.release_lane(spare);
        fault::disarm();
        // recovery: the shed op never ran, so the stream continues
        // bit-identically to an unshed twin
        let rest = front.stream(lane, task.input[20..40].to_vec()).unwrap();
        let reference = model.predict(&task.input[..40]);
        assert_eq!(first, reference[..20]);
        assert_eq!(rest, reference[20..40]);
        front.release_lane(lane);
        front.shutdown();
    }

    #[test]
    fn checkpoint_restore_round_trips_bit_exactly_at_both_precisions() {
        for make in [make_model as fn() -> super::super::Model, make_model_f32] {
            let model = Arc::new(make());
            let task = MsoTask::new(1);
            let input = &task.input[..60];
            let front = BatchFront::start(Arc::clone(&model));
            // uninterrupted reference lane
            let r = front.acquire_lane().unwrap();
            let reference = front.stream(r, input.to_vec()).unwrap();
            // interrupted lane: half the stream, then snapshot
            let a = front.acquire_lane().unwrap();
            let first = front.stream(a, input[..30].to_vec()).unwrap();
            assert_eq!(first, reference[..30]);
            let snap = front.checkpoint(a).unwrap();
            // migrate: restore onto a DIFFERENT lane of a DIFFERENT front
            let other = BatchFront::start(Arc::clone(&model));
            let b = other.acquire_lane().unwrap();
            assert_eq!(other.restore(b, snap.clone()).unwrap(), 0);
            // checkpoint ∘ restore must be the identity on lane values
            assert_eq!(other.checkpoint(b).unwrap(), snap);
            let rest = other.stream(b, input[30..].to_vec()).unwrap();
            assert_eq!(
                rest,
                reference[30..],
                "restored lane diverged from the uninterrupted stream"
            );
            // with an accumulator: the trainer snapshot round-trips and
            // commits to the same readout as the original lane
            let target: Vec<f64> =
                input[..30].iter().map(|x| 0.25 - x).collect();
            assert_eq!(
                other.train(b, input[..30].to_vec(), target).unwrap(),
                30
            );
            let snap2 = other.checkpoint(b).unwrap();
            assert!(snap2.trainer.is_some(), "trainer missing from snapshot");
            let c = other.acquire_lane().unwrap();
            assert_eq!(other.restore(c, snap2.clone()).unwrap(), 0);
            assert_eq!(other.checkpoint(c).unwrap(), snap2);
            // α above the f32 Gram noise floor so both precisions solve
            assert_eq!(other.commit(b, 1e-2).unwrap(), 1);
            assert_eq!(other.commit(c, 1e-2).unwrap(), 1);
            let gb = other.stream(b, input[30..40].to_vec()).unwrap();
            let gc = other.stream(c, input[30..40].to_vec()).unwrap();
            assert_eq!(gb, gc, "commit from a restored accumulator diverged");
            front.shutdown();
            other.shutdown();
        }
    }

    #[test]
    fn rollback_reinstalls_retained_versions_without_dropping_rows() {
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        // twin lanes with identical histories; only `a` rolls back
        let a = front.acquire_lane().unwrap();
        let twin = front.acquire_lane().unwrap();
        let t1: Vec<f64> = task.input[..80].iter().map(|x| 0.5 - 2.0 * x).collect();
        let t2: Vec<f64> = task.input[80..120].iter().map(|x| 0.5 - 2.0 * x).collect();
        for lane in [a, twin] {
            assert_eq!(front.train(lane, task.input[..80].to_vec(), t1.clone()).unwrap(), 80);
            assert_eq!(front.commit(lane, 1e-8).unwrap(), 1, "versions start at 1");
            assert_eq!(front.train(lane, task.input[80..120].to_vec(), t2.clone()).unwrap(), 120);
            assert_eq!(front.commit(lane, 1e-6).unwrap(), 2, "ids are monotonic");
        }
        // unknown version: typed refusal, lane unchanged
        let err = front.rollback(a, 7).unwrap_err();
        assert_eq!(err_code(&err), "rollback_unknown_version");
        // bounce base → v1; the twin goes straight to v1
        assert_eq!(front.rollback(a, 0).unwrap(), 0);
        assert_eq!(front.rollback(a, 1).unwrap(), 1);
        assert_eq!(front.rollback(twin, 1).unwrap(), 1);
        // same state ⊕ same readout ⇒ bit-identical continuations
        let ga = front.stream(a, task.input[120..150].to_vec()).unwrap();
        let gt = front.stream(twin, task.input[120..150].to_vec()).unwrap();
        assert_eq!(ga, gt, "rollback did not reinstall version 1 bit-exactly");
        // the accumulator survived every swap: rows continue, id mints 3
        assert_eq!(
            front.train(a, task.input[150..160].to_vec(), vec![0.0; 10]).unwrap(),
            130
        );
        assert_eq!(front.commit(a, 1e-8).unwrap(), 3);
        front.shutdown();
    }

    #[test]
    fn sweeper_panic_is_contained_and_restore_lifts_quarantine() {
        use super::super::fault;
        let _guard = fault_guard();
        let model = Arc::new(make_model());
        // dedicated sweeper thread name: the armed fuse is scoped to it,
        // so parallel tests' sweepers can never consume this fault
        let front = BatchFront::start_configured(
            Arc::clone(&model),
            0,
            "lr-fault-unit-sweeper".into(),
            usize::MAX,
        );
        let task = MsoTask::new(1);
        let victim = front.acquire_lane().unwrap();
        let bystander = front.acquire_lane().unwrap();
        let _ = front.stream(victim, task.input[..20].to_vec()).unwrap();
        let by_first = front.stream(bystander, task.input[..20].to_vec()).unwrap();
        // last-known-good checkpoint to recover the victim with
        let cp = front.checkpoint(victim).unwrap();
        // uninterrupted reference for both lanes (identical histories)
        let reference = {
            let f2 = BatchFront::start(Arc::clone(&model));
            let l = f2.acquire_lane().unwrap();
            let mut all = f2.stream(l, task.input[..20].to_vec()).unwrap();
            all.extend(f2.stream(l, task.input[20..40].to_vec()).unwrap());
            f2.shutdown();
            all
        };
        assert_eq!(by_first, reference[..20]);
        // arm: the next stateful job on THIS front's sweeper panics
        fault::target_sweeper_thread("lr-fault-unit-sweeper");
        fault::arm_sweeper_panic(1);
        let err = front
            .stream(victim, task.input[20..30].to_vec())
            .unwrap_err();
        assert_eq!(
            err_code(&err),
            "unavailable",
            "the unwound job's reply must surface as unavailable"
        );
        assert_eq!(front.sweeper_panics(), 1, "containment must count the panic");
        // the sweeper restarted in place: the untouched lane still
        // serves, bit-identically to its uninterrupted continuation
        let by_rest = front.stream(bystander, task.input[20..40].to_vec()).unwrap();
        assert_eq!(
            by_rest,
            reference[20..],
            "surviving lane lost bit-identity after the contained panic"
        );
        // the victim is quarantined with the typed code, and checkpoint
        // refuses too (it would snapshot possibly-corrupt state)
        let err = front.stream(victim, task.input[20..30].to_vec()).unwrap_err();
        assert_eq!(err_code(&err), "lane_poisoned");
        let err = front.checkpoint(victim).unwrap_err();
        assert_eq!(err_code(&err), "lane_poisoned");
        // restore IS the recovery op: quarantine lifts, state recovers
        // bit-exactly from the last checkpoint
        assert_eq!(front.restore(victim, cp).unwrap(), 0);
        let got = front.stream(victim, task.input[20..40].to_vec()).unwrap();
        assert_eq!(got, reference[20..], "recovered lane diverged");
        fault::disarm();
        front.shutdown();
    }

    #[test]
    fn trainer_budget_refuses_charges_and_releases_exactly() {
        use crate::readout::acc_cost_bytes;
        let model = Arc::new(make_model());
        let n = model.esn.n();
        let one = acc_cost_bytes(n, 1, std::mem::size_of::<f64>());
        let task = MsoTask::new(1);
        let target: Vec<f64> = task.input[..10].iter().map(|x| 1.0 - x).collect();
        // budget below one accumulator: the FIRST train refuses, typed
        let starve = BatchFront::start_configured(
            Arc::clone(&model),
            0,
            "lr-budget-starved-sweeper".into(),
            one - 1,
        );
        let lane = starve.acquire_lane().unwrap();
        let err = starve
            .train(lane, task.input[..10].to_vec(), target.clone())
            .unwrap_err();
        assert_eq!(err_code(&err), "trainer_budget");
        // the refusal happens before any state advance: the lane still
        // streams from zero state, bit-identically to the model path
        let got = starve.stream(lane, task.input[..10].to_vec()).unwrap();
        assert_eq!(got, model.predict(&task.input[..10]));
        starve.shutdown();
        // budget of exactly one accumulator: first lane trains, second
        // refuses; reset releases the charge and the second fits again
        let front = BatchFront::start_configured(
            Arc::clone(&model),
            0,
            "lr-budget-one-sweeper".into(),
            one,
        );
        let a = front.acquire_lane().unwrap();
        let b = front.acquire_lane().unwrap();
        assert_eq!(
            front.train(a, task.input[..10].to_vec(), target.clone()).unwrap(),
            10
        );
        let err = front
            .train(b, task.input[..10].to_vec(), target.clone())
            .unwrap_err();
        assert_eq!(err_code(&err), "trainer_budget");
        front.reset(a).unwrap();
        assert_eq!(
            front.train(b, task.input[..10].to_vec(), target).unwrap(),
            10
        );
        front.shutdown();
    }

    use super::super::registry::{ModelRecipe, ModelRegistry};

    fn registry_front(
        max_models: usize,
    ) -> (Arc<Model>, Arc<ModelRegistry>, Arc<BatchFront>) {
        let model = Arc::new(make_model());
        let registry =
            Arc::new(ModelRegistry::new(Arc::clone(&model), max_models));
        let front = BatchFront::start_full(
            Arc::clone(&model),
            Some(Arc::clone(&registry)),
            0,
            "lr-tenant-unit-sweeper".into(),
            usize::MAX,
            None,
        );
        (model, registry, front)
    }

    #[test]
    fn mixed_tenant_sweep_is_bit_identical_to_solo_tenant_runs() {
        // the tentpole invariant: interleaved streaming across the base
        // model and two tenants produces, per lane, exactly the bits a
        // single-model front serving only that tenant would produce
        let (model, registry, front) = registry_front(4);
        let ra = ModelRecipe::new(101, 48, 0.85, "uniform").unwrap();
        let rb = ModelRecipe::new(202, 32, 0.7, "ring").unwrap();
        let (ta, _) = registry.create(&ra).unwrap();
        let (tb, _) = registry.create(&rb).unwrap();

        let task = MsoTask::new(2);
        let base_lane = front.acquire_lane().unwrap();
        let a_lane = front.acquire_lane().unwrap();
        let b_lane = front.acquire_lane().unwrap();
        front.bind_lane_model(a_lane, ta);
        front.bind_lane_model(b_lane, tb);

        // interleave chunks across all three models on one sweeper
        let mut base_out = Vec::new();
        let mut a_out = Vec::new();
        let mut b_out = Vec::new();
        for c in 0..4 {
            let chunk = task.input[c * 25..(c + 1) * 25].to_vec();
            base_out.extend(front.stream(base_lane, chunk.clone()).unwrap());
            a_out.extend(front.stream(a_lane, chunk.clone()).unwrap());
            b_out.extend(front.stream(b_lane, chunk).unwrap());
        }

        // solo twins: each tenant alone on a dedicated single-model front
        assert_eq!(base_out, model.predict(&task.input[..100]));
        for (id, out) in [(ta, &a_out), (tb, &b_out)] {
            let solo_model = registry.get(id).unwrap();
            let solo = BatchFront::start(Arc::clone(&solo_model));
            let lane = solo.acquire_lane().unwrap();
            let mut want = Vec::new();
            for c in 0..4 {
                want.extend(
                    solo.stream(lane, task.input[c * 25..(c + 1) * 25].to_vec())
                        .unwrap(),
                );
            }
            solo.shutdown();
            assert_eq!(
                out, &want,
                "mixed-tenant sweep must be bit-identical to the solo run"
            );
        }
        // fresh tenants carry a zero readout: outputs are zeros (the
        // planes still swept — solo equality above is the real check)
        assert!(a_out.iter().all(|v| *v == 0.0));

        // per-model lane accounting
        assert_eq!(
            front.lane_counts_by_model(),
            vec![(BASE_MODEL, 1), (ta.min(tb), 1), (ta.max(tb), 1)]
        );
        front.release_lane(a_lane);
        front.release_lane(b_lane);
        front.release_lane(base_lane);
        front.shutdown();
    }

    #[test]
    fn tenant_predicts_and_unknown_models_are_typed() {
        let (model, registry, front) = registry_front(2);
        let r = ModelRecipe::new(7, 40, 0.9, "uniform").unwrap();
        let (id, _) = registry.create(&r).unwrap();
        let input: Vec<f64> = (0..12).map(|t| (t as f64 * 0.2).sin()).collect();

        // tenant predict runs that tenant's planes (zero readout ⇒ zeros)
        // while a base predict through the same sweeper is untouched
        let rx = front.predict_async_model(id, input.clone()).unwrap();
        assert_eq!(rx.recv().unwrap(), Reply::Vals(vec![0.0; 12]));
        assert_eq!(front.predict(input.clone()), model.predict(&input));

        // unknown model: typed error from the sweeper, on predicts...
        let rx = front.predict_async_model(999, input.clone()).unwrap();
        assert_eq!(rx.recv().unwrap(), Reply::Err("unknown_model"));
        // ...and on lane jobs bound to a vanished model
        let lane = front.acquire_lane().unwrap();
        front.bind_lane_model(lane, id);
        let first = front.stream(lane, input.clone()).unwrap();
        assert_eq!(first, vec![0.0; 12]);
        registry.delete(id).unwrap();
        // the bound lane keeps serving off its cached hub until released
        assert_eq!(front.stream(lane, input.clone()).unwrap(), vec![0.0; 12]);
        front.release_lane(lane);
        // a NEW binding to the deleted model is refused by the sweeper
        let lane = front.acquire_lane().unwrap();
        front.bind_lane_model(lane, id);
        let err = front.stream(lane, input).unwrap_err();
        assert_eq!(err_code(&err), "unknown_model");
        front.release_lane(lane);
        front.shutdown();
    }

    #[test]
    fn pinned_core_is_reported_when_pinning_succeeds() {
        let model = Arc::new(make_model());
        // unpinned front reports None
        let plain = BatchFront::start(Arc::clone(&model));
        assert_eq!(plain.pinned_core(), None);
        plain.shutdown();
        // pinned front reports the core iff sched_setaffinity took
        let front = BatchFront::start_full(
            Arc::clone(&model),
            None,
            0,
            "lr-pin-unit-sweeper".into(),
            usize::MAX,
            Some(0),
        );
        // serving still works either way
        let input: Vec<f64> = (0..8).map(|t| t as f64 * 0.1).collect();
        assert_eq!(front.predict(input.clone()), model.predict(&input));
        if cfg!(target_os = "linux") {
            assert_eq!(front.pinned_core(), Some(0));
        }
        front.shutdown();
    }
}
