//! Prediction service: a line-delimited JSON protocol over TCP, serving a
//! trained diagonal reservoir. This is the "request path" of the stack —
//! pure Rust, Python never involved.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"op": "predict", "input": [u0, u1, …]}     forecast 1-step-ahead for
//!                                               the whole sequence
//! → {"op": "stream", "input": [u_t]}            stateful per-connection
//!                                               streaming step
//! → {"op": "info"}
//! ← {"ok": true, "output": […], "steps_per_sec": …}
//! ```
//!
//! Each connection gets its own streaming state (slot planes); `predict`
//! requests are stateless. The engine is the O(N) diagonal step — the same
//! arithmetic as the compiled Pallas kernel, cross-validated against it in
//! the integration tests.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::linalg::Mat;
use crate::readout::Readout;
use crate::reservoir::DiagonalEsn;
use crate::util::json::{parse, Json};
use crate::util::Timer;

/// A servable model: reservoir + trained readout.
pub struct Model {
    pub esn: DiagonalEsn,
    pub readout: Readout,
}

impl Model {
    /// Stateless sequence prediction: run → features → readout.
    pub fn predict(&self, input: &[f64]) -> Vec<f64> {
        let u = Mat::from_rows(input.len(), 1, input);
        let feats = self.esn.run(&u);
        let y = self.readout.predict(&feats);
        (0..y.rows()).map(|t| y[(t, 0)]).collect()
    }
}

/// Serve `model` on `addr` (e.g. "127.0.0.1:7878"). Blocks; one thread per
/// connection. `max_requests` bounds the total requests served (tests /
/// examples); `None` runs forever.
pub fn serve(model: Arc<Model>, addr: &str, max_requests: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        let model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            let _ = handle_connection(model, stream);
        });
        served += 1;
        if let Some(max) = max_requests {
            if served >= max {
                let _ = handle.join();
                break;
            }
        } else {
            drop(handle); // detach
        }
    }
    Ok(())
}

fn handle_connection(model: Arc<Model>, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    // per-connection streaming state
    let slots = model.esn.spec.slots();
    let mut s_re = vec![0.0f64; slots];
    let mut s_im = vec![0.0f64; slots];
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let response = match handle_request(&model, &line, &mut s_re, &mut s_im) {
            Ok(json) => json,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(format!("{e:#}"))),
            ]),
        };
        out.write_all(response.to_string_compact().as_bytes())?;
        out.write_all(b"\n")?;
        let _ = peer;
    }
}

fn handle_request(
    model: &Model,
    line: &str,
    s_re: &mut [f64],
    s_im: &mut [f64],
) -> Result<Json> {
    let req = parse(line.trim())?;
    let op = req
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing 'op'"))?;
    match op {
        "info" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("n", Json::Num(model.esn.n() as f64)),
            ("slots", Json::Num(model.esn.spec.slots() as f64)),
            ("n_real", Json::Num(model.esn.spec.n_real as f64)),
            (
                "spectral_radius",
                Json::Num(model.esn.spec.radius()),
            ),
        ])),
        "predict" => {
            let input = parse_input(&req)?;
            let t = Timer::start();
            let output = model.predict(&input);
            let dt = t.elapsed_s().max(1e-12);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "output",
                    Json::Arr(output.into_iter().map(Json::Num).collect()),
                ),
                (
                    "steps_per_sec",
                    Json::Num(input.len() as f64 / dt),
                ),
            ]))
        }
        "stream" => {
            let input = parse_input(&req)?;
            let mut outs = Vec::with_capacity(input.len());
            let n = model.esn.n();
            let mut feat = vec![0.0; n];
            for &u in &input {
                model.esn.step(s_re, s_im, &[u]);
                model.esn.write_features(s_re, s_im, &mut feat);
                // y = feat·w + b
                let mut y = model.readout.b[0];
                for (j, &f) in feat.iter().enumerate() {
                    y += f * model.readout.w[(j, 0)];
                }
                outs.push(y);
            }
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("output", Json::Arr(outs.into_iter().map(Json::Num).collect())),
            ]))
        }
        "reset" => {
            s_re.fill(0.0);
            s_im.fill(0.0);
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

fn parse_input(req: &Json) -> Result<Vec<f64>> {
    req.get("input")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'input' array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric input")))
        .collect()
}

/// Minimal client for the examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn request(&mut self, req: &Json) -> Result<Json> {
        self.writer
            .write_all(req.to_string_compact().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(line.trim())
    }

    pub fn predict(&mut self, input: &[f64]) -> Result<Vec<f64>> {
        let req = Json::obj(vec![
            ("op", Json::Str("predict".into())),
            (
                "input",
                Json::Arr(input.iter().map(|&x| Json::Num(x)).collect()),
            ),
        ]);
        let resp = self.request(&req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("output")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing output"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("bad output")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::readout::{fit, Regularizer};
    use crate::reservoir::EsnConfig;
    use crate::rng::Pcg64;
    use crate::spectral::uniform::uniform_spectrum;
    use crate::tasks::mso::MsoTask;

    fn make_model() -> Model {
        let config = EsnConfig::default().with_n(30).with_sr(0.9).with_seed(1);
        let mut rng = Pcg64::new(1, 2);
        let spec = uniform_spectrum(30, 0.9, &mut rng);
        let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
        let task = MsoTask::new(1);
        let u = task.input_mat();
        let feats = esn.run(&u);
        let x = crate::tasks::mso::slice_rows(&feats, 100..400);
        let y = task.target_mat(100..400);
        let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity).unwrap();
        Model { esn, readout }
    }

    #[test]
    fn predict_and_stream_agree() {
        let model = make_model();
        let task = MsoTask::new(1);
        let input = &task.input[..50];
        let batch = model.predict(input);
        // streaming path
        let slots = model.esn.spec.slots();
        let mut s_re = vec![0.0; slots];
        let mut s_im = vec![0.0; slots];
        let mut line_out = Vec::new();
        let mut feat = vec![0.0; model.esn.n()];
        for &u in input {
            model.esn.step(&mut s_re, &mut s_im, &[u]);
            model.esn.write_features(&s_re, &s_im, &mut feat);
            let mut y = model.readout.b[0];
            for (j, &f) in feat.iter().enumerate() {
                y += f * model.readout.w[(j, 0)];
            }
            line_out.push(y);
        }
        for (a, b) in batch.iter().zip(&line_out) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn end_to_end_over_tcp() {
        let model = Arc::new(make_model());
        let addr = "127.0.0.1:47391";
        let server_model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve(server_model, addr, Some(1)).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut client = Client::connect(addr).unwrap();
        let task = MsoTask::new(1);
        let out = client.predict(&task.input[..40]).unwrap();
        assert_eq!(out.len(), 40);
        let direct = model.predict(&task.input[..40]);
        for (a, b) in out.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9);
        }
        // info op
        let resp = client
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("n").unwrap().as_usize(), Some(30));
        drop(client);
        handle.join().unwrap();
    }
}
