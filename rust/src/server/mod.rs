//! Prediction service: a line-delimited JSON protocol over TCP, serving a
//! trained diagonal reservoir from every core of the box. This is the
//! "request path" of the stack — pure Rust, Python never involved.
//!
//! The subtree splits the serving path by layer:
//!
//! | module | role |
//! |--------|------|
//! | `wire.rs` | JSON protocol, transport-agnostic request core, connection→shard binding, entry points ([`serve_on`]), [`Client`] |
//! | `poll.rs` | epoll readiness loop (Linux default): one poll thread serves every connection, thread-free idle |
//! | `shard.rs` | [`ShardedFront`]: one [`BatchFront`] per core, stream hashing + least-loaded predict deal |
//! | `front.rs` | [`BatchFront`]: one sweeper thread, job queue, streaming-lane hub, event-reply plumbing |
//! | `pool.rs` | pooled stateless predict engines, keyed by padded lane-width bucket |
//!
//! ## Event-driven accept loop
//!
//! On Linux, [`serve_on`] (and every `serve*` wrapper) defaults to the
//! epoll readiness loop in `poll.rs`: non-blocking sockets, one poll
//! thread owning every connection's read/write buffers and line
//! framing, sweeper replies delivered through an eventfd-woken
//! completion queue and flushed on socket writability. N idle streaming
//! connections cost N file descriptors and ZERO threads — the box runs
//! `S` sweepers + 1 poll thread regardless of connection count. The
//! thread-per-connection transport remains available as an A/B twin
//! (`serve_on(…, threaded = true)` / `repro serve --threaded`, and the
//! non-Linux default); both transports drive the same shard queues and
//! the same sweeper arithmetic, so responses are bit-identical between
//! them at both precisions (tested).
//!
//! ## Shard-per-core serving
//!
//! One [`BatchFront`] sweeper is single-core by design. A
//! [`ShardedFront`] runs `S` of them (default: one per available core),
//! each owning its own job queue, sweeper thread, 64-lane streaming hub,
//! and pooled predict engines — `cores × B` lanes in steady state.
//! Shards share only the read-only `Arc<Model>`; the SoA state planes
//! are per-shard, so nothing on the hot path crosses a shard boundary
//! and there are no locks to contend. Each connection hashes to a *home
//! shard* (a pure function of its connection key, which the wire layer
//! derives from the peer IP — so a reconnecting client lands on the same
//! shard) that holds its streaming state; stateless predicts are dealt
//! to the least-loaded shard. `--shards 1` reproduces the
//! single-front server bit-exactly; every shard count is bit-identical
//! on the wire regardless, because shards never share mutable state.
//!
//! ## Micro-batching front
//!
//! Connection handlers do NOT run the engine. They enqueue jobs on their
//! shard's [`BatchFront`] and its sweeper thread drains the queue:
//! concurrent `predict` requests coalesce into one stateless
//! [`BatchEsn`] sweep (one pass over `Λ`/`[W_in]_Q` amortized across the
//! batch, with the engine reused from a per-sweeper pool keyed by the
//! padded lane-width bucket), and per-connection `stream` states live as
//! lanes of one
//! persistent [`BatchEsn`] hub whose pending requests advance together
//! in a branchless masked sweep. The per-lane arithmetic is
//! bit-identical to the sequential engine, so batching is invisible to
//! clients — responses are bit-for-bit what a one-request-at-a-time
//! server would produce (tested here and in `rust/tests/pipeline.rs`).
//!
//! The sweeper supports an **adaptive hold-off window** (opt-in via
//! [`serve_with_holdoff`] / [`BatchFront::start_with_holdoff`]; [`serve`]
//! drains immediately): when the queue is shallow it waits up to the
//! configured microseconds for more jobs to coalesce; a batch-worthy
//! queue (or shutdown) drains immediately. Queue depth, sweep count,
//! hold-off, engine precision, and the shard topology are exported
//! through `info`.
//!
//! ## Precision
//!
//! The hub (and every pooled predict engine) runs at the model's
//! [`Precision`]: `F64` is the bit-exact oracle path, `F32` serves from
//! the f32 SoA lane engine — half the state traffic, twice the SIMD
//! width, the compiled HLO kernels' precision point. The wire protocol is
//! unchanged either way (JSON numbers are f64; f32→f64 widening is
//! exact), and at `F32` every path — hub lane, local fallback, and
//! [`Model::predict`] — runs the same f32 lane arithmetic, so responses
//! stay consistent across paths. The error budget of the f32 engine
//! against the f64 oracle is enforced in `rust/tests/precision.rs`.
//!
//! Every path is fused (state → readout each step): the request path does
//! `O(N + N·D_out)` work per step and never materializes a `[T × N]`
//! trajectory. Connections beyond their home hub's lane capacity fall
//! back to a local per-connection state with the same arithmetic.
//!
//! ## Online training (train-where-you-serve)
//!
//! The `train` wire op advances a connection's hub lane like `stream`
//! while streaming each step's `(features, target)` row into a per-lane
//! [`crate::readout::GramAcc`] on the lane's sweeper; `commit` solves
//! the accumulated ridge system at the hub's precision and atomically
//! hot-swaps that connection's readout (`Arc<Readout>` swap, sweeper-
//! owned). Stateless predicts keep the deployed model readout; `reset`
//! and lane recycling drop all training state. See DESIGN.md §9 and
//! `wire.rs` for the protocol and invariants.

mod binframe;
mod cluster;
pub mod fault;
mod front;
#[cfg(target_os = "linux")]
mod poll;
mod pool;
mod registry;
mod shard;
mod wire;

pub use cluster::ClusterState;
pub use front::{BatchFront, LaneSnapshot, Reply};
pub use registry::{
    mint_esn, mint_model, LambdaPrior, ModelId, ModelRecipe, ModelRegistry,
    RegistryError, BASE_MODEL, MAX_TENANT_N,
};
pub use shard::{LaneBinding, ShardedFront};
pub use wire::{
    is_retryable_code, serve, serve_on, serve_on_opts, serve_sharded,
    serve_with_holdoff, Client, ServeOpts, WireError, RETRYABLE_CODES,
};

use std::sync::Mutex;

use crate::linalg::Mat;
use crate::readout::Readout;
use crate::reservoir::{BatchEsn, DiagonalEsn, LaneReadout, QBasisEsn};

/// Native engine precision of the serving path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Bit-exact oracle path (default).
    F64,
    /// f32 SoA lane engine: 2× lanes per cache line / SIMD width; see
    /// `rust/tests/precision.rs` for the error budget vs the oracle.
    F32,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// A servable model: reservoir + trained readout + the interleaved-layout
/// serving twin ([`QBasisEsn`]) that the fused request path runs on, plus
/// the [`Precision`] every serving engine is built at. Shared read-only
/// (`Arc<Model>`) across every shard's sweeper and connection handler.
pub struct Model {
    pub esn: DiagonalEsn,
    pub qesn: QBasisEsn,
    pub readout: Readout,
    pub precision: Precision,
    /// Cached 1-lane f32 engine for the hub-less [`Model::predict`] path
    /// (the dead-sweeper fallback / test oracle used to build one per
    /// call — parameter downcast + plane allocation). Interior mutability
    /// because `predict` takes `&self` and the model is shared; the lock
    /// is uncontended off the fallback path.
    f32_local: Mutex<Option<(BatchEsn<f32>, LaneReadout<f32>)>>,
}

impl Model {
    /// Build the serving bundle at the oracle precision (derives the
    /// Appendix-A engine from `esn`).
    pub fn new(esn: DiagonalEsn, readout: Readout) -> Self {
        Self::with_precision(esn, readout, Precision::F64)
    }

    /// Build the serving bundle at an explicit precision.
    pub fn with_precision(
        esn: DiagonalEsn,
        readout: Readout,
        precision: Precision,
    ) -> Self {
        let qesn = QBasisEsn::from_diagonal(&esn);
        Self {
            esn,
            qesn,
            readout,
            precision,
            f32_local: Mutex::new(None),
        }
    }

    /// Stateless sequence prediction through the fused streaming readout
    /// — `O(N + N·D_out)` per step, no `[T × N]` materialization. Runs at
    /// the model's precision with the exact arithmetic of the batched
    /// serving path, so batching stays invisible at every precision.
    /// Multi-output models return the `[T × D_out]` predictions flattened
    /// step-major (all `D_out` values of step 0, then step 1, …) — the
    /// same shape the coalesced front path serves.
    pub fn predict(&self, input: &[f64]) -> Vec<f64> {
        match self.precision {
            Precision::F64 => {
                let u = Mat::from_rows(input.len(), 1, input);
                let y = self.qesn.run_readout(&u, &self.readout);
                flatten_step_major(&y)
            }
            Precision::F32 => {
                // mirror the front's per-lane arithmetic exactly (lane
                // results are position/batch-size independent, so a
                // 1-lane engine is bit-identical to any hub lane); the
                // engine + pre-cast readout are cached so repeated
                // fallback predicts stop paying the parameter downcast
                // and plane allocation — reset-on-use keeps the cached
                // engine indistinguishable from a fresh one.
                //
                // The cache is an optimization, never a bottleneck:
                // try_lock means concurrent fallback predicts (many
                // handler threads racing after a sweeper death) run on
                // transient engines in parallel instead of serializing
                // whole O(T·N) sweeps behind the mutex, and a poisoned
                // lock (panic mid-sweep) is recovered rather than
                // propagated — reset-on-use makes any inherited state
                // irrelevant. Both paths are bit-identical.
                use std::sync::TryLockError;
                let mut guard = match self.f32_local.try_lock() {
                    Ok(g) => Some(g),
                    Err(TryLockError::Poisoned(p)) => Some(p.into_inner()),
                    Err(TryLockError::WouldBlock) => None,
                };
                match guard.as_mut() {
                    Some(cached) => {
                        let (engine, ro) = cached.get_or_insert_with(|| {
                            (
                                BatchEsn::<f32>::with_precision(
                                    self.qesn.clone(),
                                    1,
                                ),
                                LaneReadout::new(&self.readout),
                            )
                        });
                        predict_f32_lane(engine, ro, input)
                    }
                    None => {
                        let mut engine = BatchEsn::<f32>::with_precision(
                            self.qesn.clone(),
                            1,
                        );
                        let ro = LaneReadout::new(&self.readout);
                        predict_f32_lane(&mut engine, &ro, input)
                    }
                }
            }
        }
    }
}

/// One stateless f32 1-lane prediction: zero the engine, sweep, read the
/// fused outputs. Shared by the cached and transient fallback paths of
/// [`Model::predict`] so both are the same arithmetic by construction.
fn predict_f32_lane(
    engine: &mut BatchEsn<f32>,
    ro: &LaneReadout<f32>,
    input: &[f64],
) -> Vec<f64> {
    engine.reset();
    if ro.d_out() == 1 {
        engine
            .sweep_streams_cast(&[(0, input)], ro)
            .pop()
            .unwrap_or_default()
    } else {
        let u = Mat::from_rows(input.len(), 1, input);
        let y = engine.run_readout_cast(&u, ro);
        flatten_step_major(&y)
    }
}

/// Flatten a `[T × D_out]` prediction matrix step-major — the wire shape
/// of a multi-output predict (for `D_out = 1` this is just the column).
fn flatten_step_major(y: &Mat) -> Vec<f64> {
    let (t_len, d_out) = (y.rows(), y.cols());
    let mut out = Vec::with_capacity(t_len * d_out);
    for t in 0..t_len {
        for j in 0..d_out {
            out.push(y[(t, j)]);
        }
    }
    out
}

/// Shared model fixtures for the subtree's unit tests.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::readout::{fit, Regularizer};
    use crate::reservoir::EsnConfig;
    use crate::rng::Pcg64;
    use crate::spectral::uniform::uniform_spectrum;
    use crate::tasks::mso::MsoTask;

    pub(crate) fn make_model() -> Model {
        let config = EsnConfig::default().with_n(30).with_sr(0.9).with_seed(1);
        let mut rng = Pcg64::new(1, 2);
        let spec = uniform_spectrum(30, 0.9, &mut rng);
        let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
        let task = MsoTask::new(1);
        let u = task.input_mat();
        let feats = esn.run(&u);
        let x = crate::tasks::mso::slice_rows(&feats, 100..400);
        let y = task.target_mat(100..400);
        let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity).unwrap();
        Model::new(esn, readout)
    }

    pub(crate) fn make_model_f32() -> Model {
        let m = make_model();
        Model::with_precision(m.esn, m.readout, Precision::F32)
    }

    /// A 2-output model (D_out = 2): the MSO target plus an affine twin
    /// of it, so the two trained columns are genuinely different and
    /// column truncation/aliasing is observable.
    pub(crate) fn make_model_d2() -> Model {
        let config = EsnConfig::default().with_n(30).with_sr(0.9).with_seed(1);
        let mut rng = Pcg64::new(1, 2);
        let spec = uniform_spectrum(30, 0.9, &mut rng);
        let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
        let task = MsoTask::new(1);
        let u = task.input_mat();
        let feats = esn.run(&u);
        let x = crate::tasks::mso::slice_rows(&feats, 100..400);
        let y1 = task.target_mat(100..400);
        let mut y = Mat::zeros(y1.rows(), 2);
        for t in 0..y1.rows() {
            y[(t, 0)] = y1[(t, 0)];
            y[(t, 1)] = 0.5 - 2.0 * y1[(t, 0)];
        }
        let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity).unwrap();
        Model::new(esn, readout)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{make_model, make_model_f32};
    use super::*;

    #[test]
    fn f32_model_predict_caches_its_lane_engine() {
        let model = make_model_f32();
        let input: Vec<f64> = (0..40).map(|t| (t as f64 * 0.17).sin()).collect();
        assert!(model.f32_local.lock().unwrap().is_none());
        let first = model.predict(&input);
        assert!(
            model.f32_local.lock().unwrap().is_some(),
            "first f32 predict must populate the cached engine"
        );
        // repeated predicts reuse the cached engine bit-identically
        let second = model.predict(&input);
        assert_eq!(first.len(), second.len());
        for (a, b) in first.iter().zip(&second) {
            assert!(
                (a - b).abs() == 0.0,
                "cached f32 engine changed bits: {a} vs {b}"
            );
        }
        // and a different input afterwards still starts from zero state
        let shifted: Vec<f64> = input.iter().map(|x| x + 0.5).collect();
        let fresh_model = make_model_f32();
        let want = fresh_model.predict(&shifted);
        let got = model.predict(&shifted);
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() == 0.0,
                "cached engine leaked state across predicts: {a} vs {b}"
            );
        }
    }

    #[test]
    fn f64_model_predict_unaffected_by_cache() {
        let model = make_model();
        let input: Vec<f64> = (0..30).map(|t| (t as f64 * 0.2).cos()).collect();
        let _ = model.predict(&input);
        assert!(
            model.f32_local.lock().unwrap().is_none(),
            "f64 path must not build the f32 cache"
        );
    }
}
