//! Prediction service: a line-delimited JSON protocol over TCP, serving a
//! trained diagonal reservoir. This is the "request path" of the stack —
//! pure Rust, Python never involved.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"op": "predict", "input": [u0, u1, …]}     forecast 1-step-ahead for
//!                                               the whole sequence
//! → {"op": "stream", "input": [u_t]}            stateful per-connection
//!                                               streaming step
//! → {"op": "info"}
//! ← {"ok": true, "output": […], "steps_per_sec": …}
//! ```
//!
//! ## Micro-batching front
//!
//! Connection handlers do NOT run the engine. They enqueue jobs on a
//! [`BatchFront`] and a single sweeper thread drains the queue:
//! concurrent `predict` requests coalesce into one stateless
//! [`BatchEsn`] sweep (one pass over `Λ`/`[W_in]_Q` amortized across the
//! batch), and per-connection `stream` states live as lanes of one
//! persistent [`BatchEsn`] hub whose pending requests advance together in
//! a masked sweep. The per-lane arithmetic is bit-identical to the
//! sequential engine, so batching is invisible to clients — responses are
//! bit-for-bit what a one-request-at-a-time server would produce (tested
//! here and in `rust/tests/pipeline.rs`).
//!
//! The sweeper supports an **adaptive hold-off window** (opt-in via
//! [`serve_with_holdoff`] / [`BatchFront::start_with_holdoff`]; [`serve`]
//! drains immediately): when the queue is shallow it waits up to the
//! configured microseconds for more jobs to coalesce; a batch-worthy
//! queue (or shutdown) drains immediately. The window trades per-request
//! latency on light request/response traffic for fewer, larger sweeps —
//! worthwhile only when many clients arrive together. Queue depth, sweep
//! count, hold-off, and engine precision are exported through `info`.
//!
//! ## Precision
//!
//! The hub (and every coalesced predict engine) runs at the model's
//! [`Precision`]: `F64` is the bit-exact oracle path, `F32` serves from
//! the f32 SoA lane engine — half the state traffic, twice the SIMD
//! width, the compiled HLO kernels' precision point. The wire protocol is
//! unchanged either way (JSON numbers are f64; f32→f64 widening is
//! exact), and at `F32` every path — hub lane, local fallback, and
//! [`Model::predict`] — runs the same f32 lane arithmetic, so responses
//! stay consistent across paths. The error budget of the f32 engine
//! against the f64 oracle is enforced in `rust/tests/precision.rs`.
//!
//! Every path is fused (state → readout each step): the request path does
//! `O(N + N·D_out)` work per step and never materializes a `[T × N]`
//! trajectory. Connections beyond the hub's lane capacity fall back to a
//! local per-connection state with the same arithmetic.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::linalg::Mat;
use crate::readout::Readout;
use crate::reservoir::{BatchEsn, DiagonalEsn, LaneReadout, QBasisEsn};
use crate::util::json::{parse, Json};
use crate::util::Timer;

/// Max predict requests folded into one stateless sweep.
const MAX_PREDICT_BATCH: usize = 32;
/// Streaming-state lanes in the persistent hub (connections beyond this
/// fall back to local per-connection state).
const STREAM_LANES: usize = 64;
/// Queue depth at which the sweeper skips the hold-off and drains
/// immediately — the "under load" threshold.
const HOLDOFF_DRAIN_DEPTH: usize = 4;

/// Native engine precision of the serving path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Bit-exact oracle path (default).
    F64,
    /// f32 SoA lane engine: 2× lanes per cache line / SIMD width; see
    /// `rust/tests/precision.rs` for the error budget vs the oracle.
    F32,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// A servable model: reservoir + trained readout + the interleaved-layout
/// serving twin ([`QBasisEsn`]) that the fused request path runs on, plus
/// the [`Precision`] every serving engine is built at.
pub struct Model {
    pub esn: DiagonalEsn,
    pub qesn: QBasisEsn,
    pub readout: Readout,
    pub precision: Precision,
}

impl Model {
    /// Build the serving bundle at the oracle precision (derives the
    /// Appendix-A engine from `esn`).
    pub fn new(esn: DiagonalEsn, readout: Readout) -> Self {
        Self::with_precision(esn, readout, Precision::F64)
    }

    /// Build the serving bundle at an explicit precision.
    pub fn with_precision(
        esn: DiagonalEsn,
        readout: Readout,
        precision: Precision,
    ) -> Self {
        let qesn = QBasisEsn::from_diagonal(&esn);
        Self {
            esn,
            qesn,
            readout,
            precision,
        }
    }

    /// Stateless sequence prediction through the fused streaming readout
    /// — `O(N + N·D_out)` per step, no `[T × N]` materialization. Runs at
    /// the model's precision with the exact arithmetic of the batched
    /// serving path, so batching stays invisible at every precision.
    pub fn predict(&self, input: &[f64]) -> Vec<f64> {
        match self.precision {
            Precision::F64 => {
                let u = Mat::from_rows(input.len(), 1, input);
                let y = self.qesn.run_readout(&u, &self.readout);
                (0..y.rows()).map(|t| y[(t, 0)]).collect()
            }
            Precision::F32 => {
                // mirror the front's per-lane arithmetic exactly (lane
                // results are position/batch-size independent, so a
                // 1-lane engine is bit-identical to any hub lane)
                let mut engine =
                    BatchEsn::<f32>::with_precision(self.qesn.clone(), 1);
                if self.readout.w.cols() == 1 {
                    let mut outs = engine
                        .sweep_streams(&[(0, input)], &self.readout);
                    outs.pop().unwrap_or_default()
                } else {
                    let u = Mat::from_rows(input.len(), 1, input);
                    let y = engine.run_readout(&u, &self.readout);
                    (0..y.rows()).map(|t| y[(t, 0)]).collect()
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// precision-dispatched lane engine
// ---------------------------------------------------------------------------

/// A [`BatchEsn`] at the model's serving precision, paired with the
/// readout pre-cast to that precision so per-round sweeps stay
/// allocation-free. All `BatchEsn` APIs are f64 at the boundary, so
/// dispatch is a plain match.
enum Hub {
    F64(BatchEsn<f64>, LaneReadout<f64>),
    F32(BatchEsn<f32>, LaneReadout<f32>),
}

impl Hub {
    fn new(model: &Model, lanes: usize) -> Self {
        match model.precision {
            Precision::F64 => Hub::F64(
                BatchEsn::new(model.qesn.clone(), lanes),
                LaneReadout::new(&model.readout),
            ),
            Precision::F32 => Hub::F32(
                BatchEsn::<f32>::with_precision(model.qesn.clone(), lanes),
                LaneReadout::new(&model.readout),
            ),
        }
    }

    fn sweep_streams(&mut self, reqs: &[(usize, &[f64])]) -> Vec<Vec<f64>> {
        match self {
            Hub::F64(e, ro) => e.sweep_streams_cast(reqs, ro),
            Hub::F32(e, ro) => e.sweep_streams_cast(reqs, ro),
        }
    }

    fn run_readout(&mut self, u: &Mat) -> Mat {
        match self {
            Hub::F64(e, ro) => e.run_readout_cast(u, ro),
            Hub::F32(e, ro) => e.run_readout_cast(u, ro),
        }
    }

    fn reset_lane(&mut self, lane: usize) {
        match self {
            Hub::F64(e, _) => e.reset_lane(lane),
            Hub::F32(e, _) => e.reset_lane(lane),
        }
    }
}

// ---------------------------------------------------------------------------
// micro-batching front
// ---------------------------------------------------------------------------

enum FrontJob {
    Predict {
        input: Vec<f64>,
        reply: mpsc::Sender<Vec<f64>>,
    },
    Stream {
        lane: usize,
        input: Vec<f64>,
        reply: mpsc::Sender<Vec<f64>>,
    },
    /// Zero a hub lane. `reply` is `Some` for a client-visible `reset`
    /// (synchronous), `None` when recycling a released lane.
    Reset {
        lane: usize,
        reply: Option<mpsc::Sender<()>>,
    },
}

struct FrontState {
    jobs: Vec<FrontJob>,
    shutdown: bool,
}

/// Shared queue between connection handlers and the sweeper thread.
pub struct BatchFront {
    model: Arc<Model>,
    state: Mutex<FrontState>,
    cv: Condvar,
    free_lanes: Mutex<Vec<usize>>,
    sweeper: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Coalescing window: with a shallow queue the sweeper waits up to
    /// this long for more jobs before draining; zero = drain immediately.
    holdoff: Duration,
    /// Total sweep rounds drained (metrics; exported via `info`).
    sweeps: AtomicU64,
}

impl BatchFront {
    /// Spawn the sweeper and return the shared front (no hold-off: every
    /// wake drains immediately — the legacy behavior).
    pub fn start(model: Arc<Model>) -> Arc<Self> {
        Self::start_with_holdoff(model, 0)
    }

    /// Spawn the sweeper with an adaptive micro-batch hold-off window:
    /// when fewer than a handful of jobs are queued, the sweeper waits up
    /// to `holdoff_us` µs for more to coalesce; under load (queue already
    /// batch-worthy) or on shutdown it drains immediately.
    pub fn start_with_holdoff(model: Arc<Model>, holdoff_us: u64) -> Arc<Self> {
        let front = Arc::new(Self {
            model,
            state: Mutex::new(FrontState {
                jobs: Vec::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            // lane 0 handed out first
            free_lanes: Mutex::new((0..STREAM_LANES).rev().collect()),
            sweeper: Mutex::new(None),
            holdoff: Duration::from_micros(holdoff_us),
            sweeps: AtomicU64::new(0),
        });
        let worker = Arc::clone(&front);
        let handle = std::thread::Builder::new()
            .name("lr-batch-sweeper".into())
            .spawn(move || {
                // a panic inside a sweep (engine assert) must not freeze
                // the server: mark the front dead and drop stranded jobs
                // so blocked reply receivers unblock into their fallbacks
                let res = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| worker.sweeper_loop()),
                );
                let mut st = worker.state.lock().unwrap();
                st.shutdown = true;
                st.jobs.clear();
                drop(st);
                if res.is_err() {
                    eprintln!("lr-batch-sweeper died; serving falls back to direct compute");
                }
            })
            .expect("spawn sweeper");
        *front.sweeper.lock().unwrap() = Some(handle);
        front
    }

    /// Stop the sweeper once the queue drains (idempotent).
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
        if let Some(h) = self.sweeper.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    /// Enqueue a job. Returns `false` (job dropped) when the sweeper is
    /// gone — callers use their fallback path instead of blocking.
    fn submit(&self, job: FrontJob) -> bool {
        {
            let mut st = self.state.lock().unwrap();
            if st.shutdown {
                return false;
            }
            st.jobs.push(job);
        }
        self.cv.notify_all();
        true
    }

    fn acquire_lane(&self) -> Option<usize> {
        self.free_lanes.lock().unwrap().pop()
    }

    /// Queue a zeroing of the lane, THEN return it to the free list — the
    /// queue is processed in submission order, so the next owner's first
    /// request always sees a fresh state.
    fn release_lane(&self, lane: usize) {
        self.submit(FrontJob::Reset { lane, reply: None });
        self.free_lanes.lock().unwrap().push(lane);
    }

    /// Current queued-job count (metrics; exported via `info`).
    pub fn queue_depth(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// Total sweep rounds drained so far (metrics; exported via `info`).
    pub fn sweep_count(&self) -> u64 {
        self.sweeps.load(Ordering::Relaxed)
    }

    /// Stateless prediction through the batch queue. Falls back to a
    /// direct (bit-identical, same-precision) computation if the sweeper
    /// is gone.
    pub fn predict(&self, input: Vec<f64>) -> Vec<f64> {
        let (tx, rx) = mpsc::channel();
        let queued = self.submit(FrontJob::Predict {
            input: input.clone(),
            reply: tx,
        });
        if queued {
            // a dying sweeper drops stranded jobs, so this cannot hang
            if let Ok(out) = rx.recv() {
                return out;
            }
        }
        self.model.predict(&input)
    }

    /// Streaming step(s) on a hub lane (no fallback: the state lives in
    /// the hub, so a dead sweeper is a hard error).
    pub fn stream(&self, lane: usize, input: Vec<f64>) -> Result<Vec<f64>> {
        let (tx, rx) = mpsc::channel();
        if !self.submit(FrontJob::Stream {
            lane,
            input,
            reply: tx,
        }) {
            anyhow::bail!("batch front unavailable");
        }
        rx.recv().map_err(|_| anyhow!("batch front unavailable"))
    }

    /// Synchronous client-visible lane reset.
    pub fn reset(&self, lane: usize) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        if !self.submit(FrontJob::Reset {
            lane,
            reply: Some(tx),
        }) {
            anyhow::bail!("batch front unavailable");
        }
        rx.recv().map_err(|_| anyhow!("batch front unavailable"))
    }

    fn sweeper_loop(&self) {
        // persistent streaming hub, one lane per connection, at the
        // model's precision
        let mut hub = Hub::new(&self.model, STREAM_LANES);
        loop {
            let drained = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if !st.jobs.is_empty() {
                        // shallow queue: hold off briefly so concurrent
                        // requests coalesce into one sweep; deep queue or
                        // shutdown: drain now
                        if !self.holdoff.is_zero()
                            && st.jobs.len() < HOLDOFF_DRAIN_DEPTH
                            && !st.shutdown
                        {
                            let start = Instant::now();
                            while st.jobs.len() < HOLDOFF_DRAIN_DEPTH
                                && !st.shutdown
                            {
                                match self.holdoff.checked_sub(start.elapsed())
                                {
                                    None => break,
                                    Some(left) => {
                                        let (guard, _) = self
                                            .cv
                                            .wait_timeout(st, left)
                                            .unwrap();
                                        st = guard;
                                    }
                                }
                            }
                        }
                        break std::mem::take(&mut st.jobs);
                    }
                    if st.shutdown {
                        return;
                    }
                    st = self.cv.wait(st).unwrap();
                }
            };
            self.sweeps.fetch_add(1, Ordering::Relaxed);
            self.process(&mut hub, drained);
        }
    }

    /// Drain one batch of jobs: predicts coalesce into stateless sweeps;
    /// stream/reset jobs are grouped into rounds that preserve per-lane
    /// submission order (lanes are independent, so cross-lane reordering
    /// is unobservable).
    fn process(&self, hub: &mut Hub, drained: Vec<FrontJob>) {
        let mut predicts: Vec<(Vec<f64>, mpsc::Sender<Vec<f64>>)> = Vec::new();
        let mut round: Vec<(usize, Vec<f64>, mpsc::Sender<Vec<f64>>)> = Vec::new();
        let mut in_round = [false; STREAM_LANES];

        let flush_round =
            |round: &mut Vec<(usize, Vec<f64>, mpsc::Sender<Vec<f64>>)>,
             in_round: &mut [bool; STREAM_LANES],
             hub: &mut Hub| {
                if round.is_empty() {
                    return;
                }
                let reqs: Vec<(usize, &[f64])> = round
                    .iter()
                    .map(|(lane, input, _)| (*lane, input.as_slice()))
                    .collect();
                let outs = hub.sweep_streams(&reqs);
                for ((_, _, reply), out) in round.drain(..).zip(outs) {
                    let _ = reply.send(out);
                }
                in_round.fill(false);
            };

        for job in drained {
            match job {
                FrontJob::Predict { input, reply } => predicts.push((input, reply)),
                FrontJob::Stream { lane, input, reply } => {
                    if in_round[lane] {
                        // second request for a lane: close the round first
                        // so per-lane order is preserved
                        flush_round(&mut round, &mut in_round, hub);
                    }
                    in_round[lane] = true;
                    round.push((lane, input, reply));
                }
                FrontJob::Reset { lane, reply } => {
                    if in_round[lane] {
                        flush_round(&mut round, &mut in_round, hub);
                    }
                    hub.reset_lane(lane);
                    if let Some(tx) = reply {
                        let _ = tx.send(());
                    }
                }
            }
        }
        flush_round(&mut round, &mut in_round, hub);

        // predicts: stateless — one fresh precision-matched engine per chunk
        let d_out = self.model.readout.w.cols();
        let mut start = 0;
        while start < predicts.len() {
            let chunk = &predicts[start..(start + MAX_PREDICT_BATCH).min(predicts.len())];
            start += chunk.len();
            let k = chunk.len();
            let mut engine = Hub::new(&self.model, k);
            if d_out == 1 {
                // masked sweep: exhausted lanes freeze, so a short request
                // never pays for the longest one in its batch
                let reqs: Vec<(usize, &[f64])> = chunk
                    .iter()
                    .enumerate()
                    .map(|(b, (input, _))| (b, input.as_slice()))
                    .collect();
                let outs = engine.sweep_streams(&reqs);
                for ((_, reply), out) in chunk.iter().zip(outs) {
                    let _ = reply.send(out);
                }
            } else {
                // general D_out: zero-padded full sweep (padded steps are
                // never read, so outputs are unchanged)
                let max_len = chunk.iter().map(|(i, _)| i.len()).max().unwrap_or(0);
                let mut u = Mat::zeros(max_len, k);
                for (b, (input, _)) in chunk.iter().enumerate() {
                    for (t, &v) in input.iter().enumerate() {
                        u[(t, b)] = v;
                    }
                }
                let y = engine.run_readout(&u);
                for (b, (input, reply)) in chunk.iter().enumerate() {
                    let out: Vec<f64> =
                        (0..input.len()).map(|t| y[(t, b * d_out)]).collect();
                    let _ = reply.send(out);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TCP service
// ---------------------------------------------------------------------------

/// Serve `model` on `addr` (e.g. "127.0.0.1:7878"). Blocks; one
/// lightweight handler thread per connection, all funneling into the
/// shared [`BatchFront`] with immediate drain (no hold-off — the
/// latency-safe default; high-concurrency deployments that prefer
/// deeper coalescing use [`serve_with_holdoff`]). `max_requests` bounds
/// the total connections accepted (tests / examples) — all of them are
/// joined before returning; `None` runs forever.
pub fn serve(model: Arc<Model>, addr: &str, max_requests: Option<usize>) -> Result<()> {
    serve_with_holdoff(model, addr, max_requests, 0)
}

/// [`serve`] with an explicit sweeper hold-off window (µs): with a
/// shallow queue the sweeper waits up to the window for more requests to
/// coalesce into one sweep. This trades up to `holdoff_us` of latency on
/// lightly-loaded request/response traffic for fewer, larger sweeps when
/// many clients arrive together; a batch-worthy queue always drains
/// immediately.
pub fn serve_with_holdoff(
    model: Arc<Model>,
    addr: &str,
    max_requests: Option<usize>,
    holdoff_us: u64,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    let front = BatchFront::start_with_holdoff(model, holdoff_us);
    let mut served = 0usize;
    let mut handles = Vec::new();
    let mut accept_err: Option<anyhow::Error> = None;
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                // don't early-return: the sweeper and any live handlers
                // must still be wound down below
                accept_err = Some(e.into());
                break;
            }
        };
        let front2 = Arc::clone(&front);
        let handle = std::thread::spawn(move || {
            let _ = handle_connection(front2, stream);
        });
        served += 1;
        if let Some(max) = max_requests {
            handles.push(handle);
            if served >= max {
                break;
            }
        } else {
            drop(handle); // detach
        }
    }
    for h in handles {
        let _ = h.join();
    }
    front.shutdown();
    match accept_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Per-connection fallback streaming state at the oracle precision (used
/// when the hub is full and the model serves `F64`).
struct LocalStream {
    s_re: Vec<f64>,
    s_im: Vec<f64>,
}

/// Hub-less streaming state at the model's precision: the `F64` form is
/// the legacy split-plane walk; the `F32` form is a 1-lane f32 engine
/// with its pre-cast readout (bit-identical to an f32 hub lane — lane
/// results are batch-size independent — and allocation-free per round).
enum LocalFallback {
    F64(LocalStream),
    F32(BatchEsn<f32>, LaneReadout<f32>),
}

/// Per-connection streaming identity: a hub lane is acquired LAZILY on
/// the first `stream` op (predict-only connections never occupy one) and
/// kept for the connection's lifetime; once the hub was full for this
/// connection, it sticks to the local fallback so its state never jumps
/// between hub and local.
struct ConnState {
    lane: Option<usize>,
    hub_denied: bool,
    /// Built lazily on the first hub-denied `stream` op — predict-only
    /// connections (and connections that win a hub lane) never pay for it.
    local: Option<LocalFallback>,
}

/// Construct the hub-less streaming state at the model's precision.
fn local_fallback(model: &Model) -> LocalFallback {
    match model.precision {
        Precision::F64 => {
            let slots = model.esn.spec.slots();
            LocalFallback::F64(LocalStream {
                s_re: vec![0.0f64; slots],
                s_im: vec![0.0f64; slots],
            })
        }
        Precision::F32 => LocalFallback::F32(
            BatchEsn::<f32>::with_precision(model.qesn.clone(), 1),
            LaneReadout::new(&model.readout),
        ),
    }
}

fn handle_connection(front: Arc<BatchFront>, stream: TcpStream) -> Result<()> {
    let mut conn = ConnState {
        lane: None,
        hub_denied: false,
        local: None,
    };
    let result = serve_lines(&front, &mut conn, stream);
    if let Some(l) = conn.lane {
        front.release_lane(l);
    }
    result
}

fn serve_lines(
    front: &BatchFront,
    conn: &mut ConnState,
    stream: TcpStream,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let response = match handle_request(front, conn, &line) {
            Ok(json) => json,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(format!("{e:#}"))),
            ]),
        };
        out.write_all(response.to_string_compact().as_bytes())?;
        out.write_all(b"\n")?;
    }
}

fn handle_request(
    front: &BatchFront,
    conn: &mut ConnState,
    line: &str,
) -> Result<Json> {
    let model = &front.model;
    let req = parse(line.trim())?;
    let op = req
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing 'op'"))?;
    match op {
        "info" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("n", Json::Num(model.esn.n() as f64)),
            ("slots", Json::Num(model.esn.spec.slots() as f64)),
            ("n_real", Json::Num(model.esn.spec.n_real as f64)),
            (
                "spectral_radius",
                Json::Num(model.esn.spec.radius()),
            ),
            ("precision", Json::Str(model.precision.name().into())),
            ("queue_depth", Json::Num(front.queue_depth() as f64)),
            ("sweeps", Json::Num(front.sweep_count() as f64)),
            (
                "holdoff_us",
                Json::Num(front.holdoff.as_micros() as f64),
            ),
            ("stream_lane", match conn.lane {
                Some(l) => Json::Num(l as f64),
                None => Json::Null,
            }),
        ])),
        "predict" => {
            let input = parse_input(&req)?;
            let steps = input.len();
            let t = Timer::start();
            let output = front.predict(input);
            let dt = t.elapsed_s().max(1e-12);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "output",
                    Json::Arr(output.into_iter().map(Json::Num).collect()),
                ),
                (
                    "steps_per_sec",
                    Json::Num(steps as f64 / dt),
                ),
            ]))
        }
        "stream" => {
            let input = parse_input(&req)?;
            // first stream op: try to claim a hub lane (and never switch
            // engines once this connection's streaming has started)
            if conn.lane.is_none() && !conn.hub_denied {
                conn.lane = front.acquire_lane();
                if conn.lane.is_none() {
                    conn.hub_denied = true;
                }
            }
            let outs = match conn.lane {
                Some(l) => front.stream(l, input)?,
                None => {
                    let local = conn
                        .local
                        .get_or_insert_with(|| local_fallback(model));
                    match local {
                        LocalFallback::F64(ls) => {
                            stream_local(model, &input, ls)
                        }
                        LocalFallback::F32(engine, ro) => engine
                            .sweep_streams_cast(&[(0, input.as_slice())], ro)
                            .pop()
                            .unwrap_or_default(),
                    }
                }
            };
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("output", Json::Arr(outs.into_iter().map(Json::Num).collect())),
            ]))
        }
        "reset" => {
            if let Some(l) = conn.lane {
                front.reset(l)?;
            }
            // dropping the lazy fallback IS the reset: it is rebuilt from
            // the zero state on the next hub-denied stream op
            conn.local = None;
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

/// Hub-less f64 streaming fallback: same arithmetic (and therefore the
/// same bits) as a hub lane, on connection-local slot planes.
fn stream_local(model: &Model, input: &[f64], local: &mut LocalStream) -> Vec<f64> {
    let n = model.esn.n();
    let mut outs = Vec::with_capacity(input.len());
    let mut feat = vec![0.0; n];
    for &u in input {
        model.esn.step(&mut local.s_re, &mut local.s_im, &[u]);
        model.esn.write_features(&local.s_re, &local.s_im, &mut feat);
        // y = b + feat·w (bias-first: the shared accumulation contract)
        let mut y = model.readout.b[0];
        for (j, &f) in feat.iter().enumerate() {
            y += f * model.readout.w[(j, 0)];
        }
        outs.push(y);
    }
    outs
}

fn parse_input(req: &Json) -> Result<Vec<f64>> {
    req.get("input")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'input' array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow!("non-numeric input")))
        .collect()
}

/// Minimal client for the examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn request(&mut self, req: &Json) -> Result<Json> {
        self.writer
            .write_all(req.to_string_compact().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(line.trim())
    }

    fn io_op(&mut self, op: &str, input: &[f64]) -> Result<Vec<f64>> {
        let req = Json::obj(vec![
            ("op", Json::Str(op.into())),
            (
                "input",
                Json::Arr(input.iter().map(|&x| Json::Num(x)).collect()),
            ),
        ]);
        let resp = self.request(&req)?;
        anyhow::ensure!(
            resp.get("ok").map(|j| *j == Json::Bool(true)).unwrap_or(false),
            "server error: {resp:?}"
        );
        resp.get("output")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing output"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("bad output")))
            .collect()
    }

    pub fn predict(&mut self, input: &[f64]) -> Result<Vec<f64>> {
        self.io_op("predict", input)
    }

    /// Stateful streaming step(s) on this connection's lane.
    pub fn stream(&mut self, input: &[f64]) -> Result<Vec<f64>> {
        self.io_op("stream", input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::readout::{fit, Regularizer};
    use crate::reservoir::EsnConfig;
    use crate::rng::Pcg64;
    use crate::spectral::uniform::uniform_spectrum;
    use crate::tasks::mso::MsoTask;

    fn make_model() -> Model {
        let config = EsnConfig::default().with_n(30).with_sr(0.9).with_seed(1);
        let mut rng = Pcg64::new(1, 2);
        let spec = uniform_spectrum(30, 0.9, &mut rng);
        let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
        let task = MsoTask::new(1);
        let u = task.input_mat();
        let feats = esn.run(&u);
        let x = crate::tasks::mso::slice_rows(&feats, 100..400);
        let y = task.target_mat(100..400);
        let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity).unwrap();
        Model::new(esn, readout)
    }

    fn make_model_f32() -> Model {
        let m = make_model();
        Model::with_precision(m.esn, m.readout, Precision::F32)
    }

    #[test]
    fn predict_and_stream_agree() {
        let model = make_model();
        let task = MsoTask::new(1);
        let input = &task.input[..50];
        let batch = model.predict(input);
        // streaming path (local fallback arithmetic)
        let mut local = LocalStream {
            s_re: vec![0.0; model.esn.spec.slots()],
            s_im: vec![0.0; model.esn.spec.slots()],
        };
        let line_out = stream_local(&model, input, &mut local);
        for (a, b) in batch.iter().zip(&line_out) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn batched_front_predict_is_bit_identical_to_model_predict() {
        // the batching contract: coalescing must be invisible — same bits
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(2);
        let inputs: Vec<Vec<f64>> = (0..7)
            .map(|i| task.input[i * 10..i * 10 + 35 + i].to_vec())
            .collect();
        // submit all jobs before the sweeper can drain them one by one:
        // hold the queue lock while enqueueing
        let replies: Vec<mpsc::Receiver<Vec<f64>>> = {
            let mut st = front.state.lock().unwrap();
            inputs
                .iter()
                .map(|input| {
                    let (tx, rx) = mpsc::channel();
                    st.jobs.push(FrontJob::Predict {
                        input: input.clone(),
                        reply: tx,
                    });
                    rx
                })
                .collect()
        };
        front.cv.notify_all();
        for (input, rx) in inputs.iter().zip(replies) {
            let batched = rx.recv().unwrap();
            let sequential = model.predict(input);
            assert_eq!(batched.len(), sequential.len());
            for (a, b) in batched.iter().zip(&sequential) {
                assert!(
                    (a - b).abs() == 0.0,
                    "batched predict must be bit-identical: {a} vs {b}"
                );
            }
        }
        front.shutdown();
    }

    #[test]
    fn hub_lanes_are_isolated_and_match_sequential_streaming() {
        let model = Arc::new(make_model());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let a = front.acquire_lane().unwrap();
        let b = front.acquire_lane().unwrap();
        assert_ne!(a, b);
        // interleave chunks on two lanes
        let in_a = &task.input[..40];
        let in_b = &task.input[200..230];
        let mut got_a = front.stream(a, in_a[..15].to_vec()).unwrap();
        let mut got_b = front.stream(b, in_b[..7].to_vec()).unwrap();
        got_a.extend(front.stream(a, in_a[15..].to_vec()).unwrap());
        got_b.extend(front.stream(b, in_b[7..].to_vec()).unwrap());
        // reference: each stream alone
        let reference = |input: &[f64]| {
            let mut local = LocalStream {
                s_re: vec![0.0; model.esn.spec.slots()],
                s_im: vec![0.0; model.esn.spec.slots()],
            };
            stream_local(&model, input, &mut local)
        };
        for (got, want) in [(got_a, reference(in_a)), (got_b, reference(in_b))] {
            assert_eq!(got.len(), want.len());
            for (x, y) in got.iter().zip(&want) {
                assert!((x - y).abs() < 1e-10, "{x} vs {y}");
            }
        }
        // reset isolates too: lane a resets, lane b keeps its state
        front.reset(a).unwrap();
        let fresh = front.stream(a, in_a[..5].to_vec()).unwrap();
        let ref_a = reference(in_a);
        for (x, y) in fresh.iter().zip(&ref_a[..5]) {
            assert!((x - y).abs() < 1e-10);
        }
        front.release_lane(a);
        front.release_lane(b);
        front.shutdown();
    }

    #[test]
    fn end_to_end_over_tcp() {
        let model = Arc::new(make_model());
        let addr = "127.0.0.1:47391";
        let server_model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve(server_model, addr, Some(1)).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut client = Client::connect(addr).unwrap();
        let task = MsoTask::new(1);
        let out = client.predict(&task.input[..40]).unwrap();
        assert_eq!(out.len(), 40);
        let direct = model.predict(&task.input[..40]);
        for (a, b) in out.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9);
        }
        // info op
        let resp = client
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("n").unwrap().as_usize(), Some(30));
        drop(client);
        handle.join().unwrap();
    }

    #[test]
    fn f32_front_predict_matches_f32_model_predict_bitwise() {
        // precision consistency contract: at F32 every path (coalesced
        // sweep, fallback, Model::predict) runs the same f32 lane
        // arithmetic, so responses stay bit-identical across paths
        let model = Arc::new(make_model_f32());
        assert_eq!(model.precision, Precision::F32);
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(2);
        for i in 0..5 {
            let input = task.input[i * 13..i * 13 + 30 + i].to_vec();
            let batched = front.predict(input.clone());
            let direct = model.predict(&input);
            assert_eq!(batched.len(), direct.len());
            for (a, b) in batched.iter().zip(&direct) {
                assert!(
                    (a - b).abs() == 0.0,
                    "f32 batched predict must be bit-identical: {a} vs {b}"
                );
            }
            // and the f32 result is close to (but generally not equal to)
            // the f64 oracle
            let oracle = {
                let u = Mat::from_rows(input.len(), 1, &input);
                let y = model.qesn.run_readout(&u, &model.readout);
                (0..y.rows()).map(|t| y[(t, 0)]).collect::<Vec<f64>>()
            };
            let scale =
                oracle.iter().fold(1.0f64, |m, x| m.max(x.abs()));
            for (a, b) in batched.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-3 * scale, "{a} vs oracle {b}");
            }
        }
        front.shutdown();
    }

    #[test]
    fn f32_hub_streaming_matches_single_lane_f32_reference() {
        let model = Arc::new(make_model_f32());
        let front = BatchFront::start(Arc::clone(&model));
        let task = MsoTask::new(1);
        let lane = front.acquire_lane().unwrap();
        let input = &task.input[..48];
        let mut got = front.stream(lane, input[..17].to_vec()).unwrap();
        got.extend(front.stream(lane, input[17..].to_vec()).unwrap());
        // reference: a private 1-lane f32 engine (the F32 local fallback)
        let mut reference =
            BatchEsn::<f32>::with_precision(model.qesn.clone(), 1);
        let want = reference
            .sweep_streams(&[(0, input)], &model.readout)
            .pop()
            .unwrap();
        assert_eq!(got.len(), want.len());
        for (t, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() == 0.0,
                "f32 hub lane diverged from 1-lane reference at t={t}: {a} vs {b}"
            );
        }
        front.release_lane(lane);
        front.shutdown();
    }

    #[test]
    fn holdoff_front_coalesces_and_counts_sweeps() {
        let model = Arc::new(make_model());
        // generous hold-off so concurrently-submitted jobs coalesce
        let front = BatchFront::start_with_holdoff(Arc::clone(&model), 2_000);
        let task = MsoTask::new(2);
        let inputs: Vec<Vec<f64>> = (0..3)
            .map(|i| task.input[i * 11..i * 11 + 25 + i].to_vec())
            .collect();
        let mut workers = Vec::new();
        for input in inputs {
            let f = Arc::clone(&front);
            let m = Arc::clone(&model);
            workers.push(std::thread::spawn(move || {
                let got = f.predict(input.clone());
                let want = m.predict(&input);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() == 0.0);
                }
            }));
        }
        for w in workers {
            w.join().unwrap();
        }
        // all replies delivered ⇒ at least one sweep ran; with the
        // hold-off they usually coalesce into exactly one
        assert!(front.sweep_count() >= 1);
        assert_eq!(front.queue_depth(), 0);
        front.shutdown();
    }

    #[test]
    fn info_reports_precision_and_sweeper_metrics() {
        let model = Arc::new(make_model_f32());
        let addr = "127.0.0.1:47417";
        let server_model = Arc::clone(&model);
        let handle = std::thread::spawn(move || {
            serve(server_model, addr, Some(1)).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut client = Client::connect(addr).unwrap();
        let task = MsoTask::new(1);
        // drive at least one sweep through the front
        let out = client.predict(&task.input[..20]).unwrap();
        assert_eq!(out.len(), 20);
        let resp = client
            .request(&Json::obj(vec![("op", Json::Str("info".into()))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            resp.get("precision").and_then(Json::as_str),
            Some("f32")
        );
        assert!(resp.get("sweeps").and_then(Json::as_f64).unwrap() >= 1.0);
        assert!(resp.get("queue_depth").and_then(Json::as_f64).is_some());
        // serve() runs with immediate drain; the hold-off is opt-in via
        // serve_with_holdoff / start_with_holdoff
        assert_eq!(
            resp.get("holdoff_us").and_then(Json::as_f64),
            Some(0.0)
        );
        drop(client);
        handle.join().unwrap();
    }
}
