//! Negotiated length-prefixed binary frame protocol — the wire-path
//! fast lane next to the line-delimited JSON protocol.
//!
//! Motivation: at high REQUEST rates the serving ceiling is not the
//! O(N) sweep but the per-request `f64` Display/parse on the poll
//! thread — formatting a predict-sized float array costs more cycles
//! than computing it. Binary frames carry raw little-endian IEEE-754
//! bits in both directions, so the hot path stops paying for float
//! formatting entirely.
//!
//! Negotiation (first bytes of a fresh connection, server side):
//!
//! ```text
//!   first byte != 'L' ──────────────► JSON (today's protocol, default)
//!   "LRBF" + version + 3 reserved ──► server acks 8 bytes, conn is
//!                                     binary-framed from then on
//!   "L..." that diverges from magic ► JSON (bytes are the line start)
//!   "LRBF" + wrong version ─────────► typed `bad_frame` error, close
//! ```
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//!   request:  [u32 body_len] [u8 op] [u8 flags]
//!             [f64 deadline_ms]?   (flags bit 0)
//!             [f64 model]?         (flags bit 1)
//!             [payload…]           (f32 wide when flags bit 2)
//!   response: [u32 body_len] [u8 status] [payload…]
//! ```
//!
//! Compact ops (ping/info/predict/stream/train/commit/rollback/reset)
//! carry raw float arrays; every other op tunnels its compact JSON
//! request text in an `OP_JSON` frame and is parsed by the SAME
//! [`parse_op`] as the JSON transport — dispatch is shared op-for-op,
//! so the two protocols cannot drift. Responses are shape-matched from
//! the SAME [`Json`] the JSON transport would serialize: float-shaped
//! responses go out as raw `f64` bits, everything else as compact JSON
//! text (`OK_JSON`), so a binary client reconstructs a `Json` value
//! structurally identical to what a JSON client parses — bit-exact
//! floats included (the JSON path prints shortest-round-trip).
//!
//! Error parity: decode failures that keep the stream framed (the body
//! length was consumed exactly) answer the typed `bad_frame` error and
//! the connection lives on; failures that LOSE framing (oversized
//! length prefix, a frame torn by EOF) answer `bad_frame` and close —
//! the length field can no longer be trusted. Semantic validation
//! (deadline range, row caps, alpha sign) raises the same error text as
//! the JSON parser, answered as an ordinary error response.

use std::io::{ErrorKind, Read};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::util::json::{parse, Json};

use super::registry::ModelId;
use super::wire::{
    coded_error, error_response, parse_op, Op, DEFAULT_COMMIT_ALPHA,
    MAX_TRAIN_ROWS_PER_OP,
};

// ---------------------------------------------------------------------------
// hello
// ---------------------------------------------------------------------------

pub(crate) const MAGIC: [u8; 4] = *b"LRBF";
pub(crate) const VERSION: u8 = 1;
pub(crate) const HELLO_LEN: usize = 8;

/// Client → server upgrade hello: magic, version, 3 reserved zeros.
pub(crate) fn client_hello() -> [u8; HELLO_LEN] {
    [MAGIC[0], MAGIC[1], MAGIC[2], MAGIC[3], VERSION, 0, 0, 0]
}

/// Server → client upgrade ack. Byte 5 distinguishes the ack from an
/// echoed hello so a cross-wired client can't mistake its own bytes.
pub(crate) fn server_hello() -> [u8; HELLO_LEN] {
    [MAGIC[0], MAGIC[1], MAGIC[2], MAGIC[3], VERSION, 0xAC, 0, 0]
}

/// One frame body longer than this is not protocol traffic — the same
/// bound as the JSON transport's `MAX_LINE_BYTES`, so neither codec
/// buffers unboundedly.
pub(crate) const MAX_FRAME_BYTES: usize = 64 << 20;

// request op bytes
const OP_PING: u8 = 1;
const OP_INFO: u8 = 2;
const OP_PREDICT: u8 = 3;
const OP_STREAM: u8 = 4;
const OP_TRAIN: u8 = 5;
const OP_COMMIT: u8 = 6;
const OP_ROLLBACK: u8 = 7;
const OP_RESET: u8 = 8;
/// Tunnel: the body is the compact JSON request text, parsed by
/// [`parse_op`] — covers checkpoint/restore/migrate/registry/drain ops
/// whose payloads are structured, not float arrays.
const OP_JSON: u8 = 9;

// request flags
const FLAG_DEADLINE: u8 = 1 << 0;
const FLAG_MODEL: u8 = 1 << 1;
/// Payload floats are `f32` little-endian (half the wire bytes); the
/// server widens exactly (`f32 as f64` is value-preserving).
const FLAG_F32: u8 = 1 << 2;
/// A scalar operand follows the header (`commit` alpha / `rollback`
/// version); absent means the op's documented default.
const FLAG_SCALAR: u8 = 1 << 3;
const FLAG_KNOWN: u8 = FLAG_DEADLINE | FLAG_MODEL | FLAG_F32 | FLAG_SCALAR;

// response status bytes
const ST_OK_VALUES: u8 = 0;
const ST_ERR: u8 = 1;
const ST_OK_JSON: u8 = 2;
const ST_OK_SCALAR: u8 = 3;
const ST_OK_PREDICT: u8 = 4;
const ST_OK_EMPTY: u8 = 5;

// scalar response kinds
const SC_ROWS: u8 = 0;
const SC_VERSION: u8 = 1;

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Result of scanning a read buffer for the next complete frame.
pub(crate) enum Framing {
    /// The buffer holds no complete frame yet — keep reading.
    NeedMore,
    /// `rbuf[start..end]` is the next frame body; the following frame
    /// begins at `next` (== `end`).
    Frame { start: usize, end: usize, next: usize },
    /// The length prefix exceeds [`MAX_FRAME_BYTES`]: framing is lost
    /// (the field can't be trusted as a skip distance) — answer
    /// `bad_frame`, close.
    Oversized,
}

/// Bounds of the next complete frame at/after `from` — the binary twin
/// of the JSON transport's `next_line_bounds`. Pure scan; the caller
/// compacts the buffer once per readiness round.
pub(crate) fn split_frame(rbuf: &[u8], from: usize) -> Framing {
    let avail = rbuf.len().saturating_sub(from);
    if avail < 4 {
        return Framing::NeedMore;
    }
    let len = u32::from_le_bytes([
        rbuf[from],
        rbuf[from + 1],
        rbuf[from + 2],
        rbuf[from + 3],
    ]) as usize;
    if len > MAX_FRAME_BYTES {
        return Framing::Oversized;
    }
    if avail < 4 + len {
        return Framing::NeedMore;
    }
    Framing::Frame {
        start: from + 4,
        end: from + 4 + len,
        next: from + 4 + len,
    }
}

/// Outcome of a blocking frame read (threaded transport + client side).
pub(crate) enum ReadFrame {
    /// Clean EOF between frames.
    Eof,
    /// EOF tore a frame mid-prefix or mid-body — `bad_frame`, close.
    TornEof,
    /// Length prefix exceeds the cap — `bad_frame`, close.
    Oversized,
    /// One complete frame body.
    Frame(Vec<u8>),
}

/// Read exactly one length-prefixed frame from a blocking stream.
pub(crate) fn read_frame(r: &mut impl Read) -> std::io::Result<ReadFrame> {
    let mut len4 = [0u8; 4];
    match read_full(r, &mut len4)? {
        0 => return Ok(ReadFrame::Eof),
        4 => {}
        _ => return Ok(ReadFrame::TornEof),
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME_BYTES {
        return Ok(ReadFrame::Oversized);
    }
    let mut body = vec![0u8; len];
    if read_full(r, &mut body)? < len {
        return Ok(ReadFrame::TornEof);
    }
    Ok(ReadFrame::Frame(body))
}

/// Fill `buf` as far as the stream allows; returns bytes read (short on
/// EOF). Interrupted reads retry in place.
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

/// The typed refusal a transport writes before closing a connection
/// whose binary framing is lost (torn or oversized frame).
pub(crate) fn bad_frame_close_frame() -> Vec<u8> {
    let mut out = Vec::new();
    encode_response(&error_response(&coded_error("bad_frame")), &mut out);
    out
}

// ---------------------------------------------------------------------------
// request codec
// ---------------------------------------------------------------------------

/// Encode a request `Json` (the same object a JSON client would print)
/// as one binary frame. Float-array ops go out compact — raw `f64`
/// bits, no formatting; anything that doesn't fit the compact form
/// (structured payloads, or fields the compact header can't carry)
/// tunnels its compact JSON text in an [`OP_JSON`] frame, so the server
/// applies literally the same parse — identical errors included.
pub(crate) fn encode_request(req: &Json) -> Vec<u8> {
    match compact_request(req) {
        Some(frame) => frame,
        None => {
            let text = req.to_string_compact();
            let mut out = Vec::with_capacity(4 + 2 + text.len());
            out.extend_from_slice(&((text.len() + 2) as u32).to_le_bytes());
            out.push(OP_JSON);
            out.push(0); // flags
            out.extend_from_slice(text.as_bytes());
            out
        }
    }
}

/// Try the compact encoding; `None` falls back to the JSON tunnel.
fn compact_request(req: &Json) -> Option<Vec<u8>> {
    let op_name = req.get("op").and_then(Json::as_str)?;
    // header floats: absent (None in the Option) or the raw value; a
    // non-numeric field can't ride the header — tunnel it so the
    // server's JSON parser produces the identical type error
    let deadline = match req.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(Json::Num(x)) => Some(*x),
        Some(_) => return None,
    };
    let model = match req.get("model") {
        None | Some(Json::Null) => None,
        Some(Json::Num(x)) => Some(*x),
        Some(_) => return None,
    };
    let (op_byte, scalar, payload): (u8, Option<f64>, Vec<f64>) = match op_name {
        "ping" => (OP_PING, None, Vec::new()),
        "info" => (OP_INFO, None, Vec::new()),
        "reset" => (OP_RESET, None, Vec::new()),
        "predict" => (OP_PREDICT, None, nums(req.get("input")?)?),
        "stream" => (OP_STREAM, None, nums(req.get("input")?)?),
        "train" => {
            let input = nums(req.get("input")?)?;
            let target = nums(req.get("target")?)?;
            if input.len() != target.len() {
                // the compact frame shares one count for both arrays;
                // let the JSON parser issue its mismatch error
                return None;
            }
            let mut both = input;
            both.extend_from_slice(&target);
            (OP_TRAIN, None, both)
        }
        "commit" => match req.get("alpha") {
            None => (OP_COMMIT, None, Vec::new()),
            Some(Json::Num(a)) => (OP_COMMIT, Some(*a), Vec::new()),
            // alpha:null errors "non-numeric" in the JSON parser —
            // tunnel so the refusal is identical
            Some(_) => return None,
        },
        "rollback" => match req.get("version") {
            None | Some(Json::Null) => (OP_ROLLBACK, None, Vec::new()),
            Some(Json::Num(v)) => (OP_ROLLBACK, Some(*v), Vec::new()),
            Some(_) => return None,
        },
        _ => return None,
    };
    let mut flags = 0u8;
    let mut body_len = 2usize;
    if deadline.is_some() {
        flags |= FLAG_DEADLINE;
        body_len += 8;
    }
    if model.is_some() {
        flags |= FLAG_MODEL;
        body_len += 8;
    }
    if scalar.is_some() {
        flags |= FLAG_SCALAR;
        body_len += 8;
    }
    if !payload.is_empty() || matches!(op_byte, OP_PREDICT | OP_STREAM | OP_TRAIN)
    {
        body_len += 4 + 8 * payload.len();
    }
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(op_byte);
    out.push(flags);
    if let Some(ms) = deadline {
        out.extend_from_slice(&ms.to_le_bytes());
    }
    if let Some(m) = model {
        out.extend_from_slice(&m.to_le_bytes());
    }
    if let Some(s) = scalar {
        out.extend_from_slice(&s.to_le_bytes());
    }
    if matches!(op_byte, OP_PREDICT | OP_STREAM) {
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        for v in &payload {
            out.extend_from_slice(&v.to_le_bytes());
        }
    } else if op_byte == OP_TRAIN {
        out.extend_from_slice(&((payload.len() / 2) as u32).to_le_bytes());
        for v in &payload {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    Some(out)
}

/// All-numeric JSON array → raw values (`None` → tunnel).
fn nums(v: &Json) -> Option<Vec<f64>> {
    let arr = v.as_arr()?;
    let mut out = Vec::with_capacity(arr.len());
    for e in arr {
        out.push(e.as_f64()?);
    }
    Some(out)
}

/// Frame-shape violation: the typed `bad_frame` refusal (the stream
/// stays framed — the body length was consumed exactly — so the
/// connection survives; only torn/oversized framing closes it).
fn bad_frame(what: &str) -> anyhow::Error {
    coded_error("bad_frame").context(format!("binary frame: {what}"))
}

/// Decode one request frame body into the SAME `(op, deadline, model)`
/// tuple [`parse_op`] produces — semantic validation mirrors the JSON
/// parser clause for clause (same error text), and tunnel frames go
/// through `parse_op` itself.
pub(crate) fn decode_request(
    body: &[u8],
) -> Result<(Op, Option<Duration>, Option<ModelId>)> {
    let mut c = Cur { buf: body, pos: 0 };
    let op_byte = c.u8()?;
    let flags = c.u8()?;
    if flags & !FLAG_KNOWN != 0 {
        return Err(bad_frame("unknown flag bits"));
    }
    if op_byte == OP_JSON {
        if flags != 0 {
            return Err(bad_frame("tunnel frame carries header flags"));
        }
        let text = std::str::from_utf8(&body[c.pos..])
            .map_err(|_| bad_frame("tunnel body is not UTF-8"))?;
        return parse_op(text);
    }
    // header fields first (fixed order), mirroring parse_op's
    // validation messages exactly
    let deadline = if flags & FLAG_DEADLINE != 0 {
        let ms = c.f64()?;
        anyhow::ensure!(
            ms.is_finite() && ms >= 0.0,
            "'deadline_ms' must be a finite non-negative number"
        );
        Some(
            Duration::try_from_secs_f64(ms / 1000.0)
                .map_err(|_| anyhow!("'deadline_ms' out of range"))?,
        )
    } else {
        None
    };
    let model = if flags & FLAG_MODEL != 0 {
        let x = c.f64()?;
        anyhow::ensure!(
            x.is_finite() && x >= 0.0 && x.fract() == 0.0,
            "'model' must be a non-negative integer"
        );
        Some(x as u64)
    } else {
        None
    };
    let scalar = if flags & FLAG_SCALAR != 0 {
        Some(c.f64()?)
    } else {
        None
    };
    let wide = flags & FLAG_F32 == 0;
    let op = match op_byte {
        OP_PING => Op::Ping,
        OP_INFO => Op::Info,
        OP_RESET => Op::Reset,
        OP_PREDICT => Op::Predict(c.floats(c.u32()? as usize, wide)?),
        OP_STREAM => Op::Stream(c.floats(c.u32()? as usize, wide)?),
        OP_TRAIN => {
            let n = c.u32()? as usize;
            anyhow::ensure!(
                n <= MAX_TRAIN_ROWS_PER_OP,
                "train op too large ({} rows; max {MAX_TRAIN_ROWS_PER_OP} \
                 per op — split the stream across multiple ops)",
                n
            );
            let input = c.floats(n, wide)?;
            let target = c.floats(n, wide)?;
            Op::Train { input, target }
        }
        OP_COMMIT => {
            let alpha = scalar.unwrap_or(DEFAULT_COMMIT_ALPHA);
            anyhow::ensure!(
                alpha.is_finite() && alpha >= 0.0,
                "'alpha' must be a finite non-negative number"
            );
            Op::Commit { alpha }
        }
        OP_ROLLBACK => {
            let version = match scalar {
                None => 0,
                Some(v) => {
                    anyhow::ensure!(
                        v.is_finite() && v >= 0.0 && v.fract() == 0.0,
                        "'version' must be a non-negative integer"
                    );
                    v as u64
                }
            };
            Op::Rollback { version }
        }
        other => return Err(bad_frame(&format!("unknown op byte {other}"))),
    };
    if c.pos != body.len() {
        return Err(bad_frame("trailing bytes after the payload"));
    }
    // commit/rollback took their scalar; a scalar on any other op is a
    // shape violation
    if scalar.is_some() && !matches!(op, Op::Commit { .. } | Op::Rollback { .. })
    {
        return Err(bad_frame("scalar operand on a non-scalar op"));
    }
    Ok((op, deadline, model))
}

/// Bounds-checked little-endian reader over one frame body.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Cur<'_> {
    fn need(&self, n: usize) -> Result<()> {
        if self.buf.len() - self.pos < n {
            return Err(bad_frame("truncated body"));
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8> {
        self.need(1)?;
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32> {
        self.need(4)?;
        let v = u32::from_le_bytes(
            self.buf[self.pos..self.pos + 4].try_into().unwrap(),
        );
        self.pos += 4;
        Ok(v)
    }

    fn f64(&mut self) -> Result<f64> {
        self.need(8)?;
        let v = f64::from_le_bytes(
            self.buf[self.pos..self.pos + 8].try_into().unwrap(),
        );
        self.pos += 8;
        Ok(v)
    }

    /// `n` floats at the frame's declared width; `f32` payloads widen
    /// exactly (every `f32` — NaN payloads aside — has one `f64` value).
    fn floats(&mut self, n: usize, wide: bool) -> Result<Vec<f64>> {
        let sz = if wide { 8 } else { 4 };
        self.need(n.checked_mul(sz).ok_or_else(|| bad_frame("count overflow"))?)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if wide {
                out.push(f64::from_le_bytes(
                    self.buf[self.pos..self.pos + 8].try_into().unwrap(),
                ));
                self.pos += 8;
            } else {
                out.push(f32::from_le_bytes(
                    self.buf[self.pos..self.pos + 4].try_into().unwrap(),
                ) as f64);
                self.pos += 4;
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// response codec
// ---------------------------------------------------------------------------

/// Encode the SAME response `Json` the JSON transport would print as a
/// binary frame, appended to `out`. Float-shaped responses (predict /
/// stream / scalar acks / errors) go compact — raw `f64` bits;
/// everything else (`info`, `pong`, `checkpoint`, registry acks, …)
/// carries its compact JSON text, so EVERY response a server can build
/// has a frame and parity is total by construction.
pub(crate) fn encode_response(resp: &Json, out: &mut Vec<u8>) {
    let at = out.len();
    out.extend_from_slice(&[0, 0, 0, 0]); // length back-patched below
    compact_response(resp, out);
    let body_len = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&body_len.to_le_bytes());
}

fn compact_response(resp: &Json, out: &mut Vec<u8>) {
    if let Json::Obj(m) = resp {
        match m.get("ok") {
            Some(Json::Bool(true)) => {
                if m.len() == 1 {
                    out.push(ST_OK_EMPTY);
                    return;
                }
                if m.len() == 3 {
                    if let (Some(vals), Some(Json::Num(sps))) =
                        (m.get("output").and_then(num_arr), m.get("steps_per_sec"))
                    {
                        out.push(ST_OK_PREDICT);
                        push_vals(&vals, out);
                        out.extend_from_slice(&sps.to_le_bytes());
                        return;
                    }
                }
                if m.len() == 2 {
                    if let Some(vals) = m.get("output").and_then(num_arr) {
                        out.push(ST_OK_VALUES);
                        push_vals(&vals, out);
                        return;
                    }
                    if let Some(Json::Num(rows)) = m.get("rows") {
                        out.push(ST_OK_SCALAR);
                        out.push(SC_ROWS);
                        out.extend_from_slice(&rows.to_le_bytes());
                        return;
                    }
                    if let Some(Json::Num(v)) = m.get("version") {
                        out.push(ST_OK_SCALAR);
                        out.push(SC_VERSION);
                        out.extend_from_slice(&v.to_le_bytes());
                        return;
                    }
                }
            }
            Some(Json::Bool(false)) => {
                // the error_response shape: error + optional code/addr
                // strings and nothing else
                let err = m.get("error").and_then(Json::as_str);
                let extras_ok = m
                    .keys()
                    .all(|k| matches!(k.as_str(), "ok" | "error" | "code" | "addr"));
                let code = m.get("code").map(|c| c.as_str());
                let addr = m.get("addr").map(|a| a.as_str());
                if let (Some(err), true, None | Some(Some(_)), None | Some(Some(_))) =
                    (err, extras_ok, code, addr)
                {
                    out.push(ST_ERR);
                    push_str(err, out);
                    push_str(code.flatten().unwrap_or(""), out);
                    push_str(addr.flatten().unwrap_or(""), out);
                    return;
                }
            }
            _ => {}
        }
    }
    // universal fallback: the compact JSON text (still no float
    // formatting on the hot ops — only structured responses land here)
    out.push(ST_OK_JSON);
    out.extend_from_slice(resp.to_string_compact().as_bytes());
}

fn num_arr(v: &Json) -> Option<Vec<f64>> {
    nums(v)
}

fn push_vals(vals: &[f64], out: &mut Vec<u8>) {
    out.extend_from_slice(&(vals.len() as u32).to_le_bytes());
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn push_str(s: &str, out: &mut Vec<u8>) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Decode one response frame body back into the `Json` a JSON-transport
/// client would have parsed — structurally identical (object keys are
/// canonical BTreeMap order on both paths), floats bit-exact.
pub(crate) fn decode_response(body: &[u8]) -> Result<Json> {
    let mut c = Cur { buf: body, pos: 0 };
    let status = c.u8()?;
    let json = match status {
        ST_OK_EMPTY => Json::obj(vec![("ok", Json::Bool(true))]),
        ST_OK_VALUES => {
            let n = c.u32()? as usize;
            let vals = c.floats(n, true)?;
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("output", Json::Arr(vals.into_iter().map(Json::Num).collect())),
            ])
        }
        ST_OK_PREDICT => {
            let n = c.u32()? as usize;
            let vals = c.floats(n, true)?;
            let sps = c.f64()?;
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("output", Json::Arr(vals.into_iter().map(Json::Num).collect())),
                ("steps_per_sec", Json::Num(sps)),
            ])
        }
        ST_OK_SCALAR => {
            let kind = c.u8()?;
            let v = c.f64()?;
            let key = match kind {
                SC_ROWS => "rows",
                SC_VERSION => "version",
                other => {
                    return Err(bad_frame(&format!("unknown scalar kind {other}")))
                }
            };
            Json::obj(vec![("ok", Json::Bool(true)), (key, Json::Num(v))])
        }
        ST_ERR => {
            let err = c.str()?;
            let code = c.str()?;
            let addr = c.str()?;
            let mut pairs = vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(err)),
            ];
            if !code.is_empty() {
                pairs.push(("code", Json::Str(code)));
            }
            if !addr.is_empty() {
                pairs.push(("addr", Json::Str(addr)));
            }
            Json::obj(pairs)
        }
        ST_OK_JSON => {
            let text = std::str::from_utf8(&body[c.pos..])
                .map_err(|_| bad_frame("response text is not UTF-8"))?;
            return parse(text);
        }
        other => return Err(bad_frame(&format!("unknown status byte {other}"))),
    };
    if c.pos != body.len() {
        return Err(bad_frame("trailing bytes after the payload"));
    }
    Ok(json)
}

impl Cur<'_> {
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        self.need(n)?;
        let s = std::str::from_utf8(&self.buf[self.pos..self.pos + n])
            .map_err(|_| bad_frame("string field is not UTF-8"))?
            .to_string();
        self.pos += n;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::wire::WireError;

    /// Exact-bits signature of a parsed op tuple, so JSON-parsed and
    /// binary-decoded requests can be compared without `Op: PartialEq`.
    fn sig(t: &(Op, Option<Duration>, Option<ModelId>)) -> String {
        let bits = |v: &[f64]| -> Vec<u64> { v.iter().map(|x| x.to_bits()).collect() };
        let body = match &t.0 {
            Op::Info => "info".to_string(),
            Op::Ping => "ping".to_string(),
            Op::Predict(v) => format!("predict {:?}", bits(v)),
            Op::Stream(v) => format!("stream {:?}", bits(v)),
            Op::Train { input, target } => {
                format!("train {:?} {:?}", bits(input), bits(target))
            }
            Op::Commit { alpha } => format!("commit {}", alpha.to_bits()),
            Op::Rollback { version } => format!("rollback {version}"),
            Op::Checkpoint => "checkpoint".to_string(),
            Op::Restore(_) => "restore".to_string(),
            Op::Reset => "reset".to_string(),
            Op::Migrate { shard } => format!("migrate {shard:?}"),
            Op::MigrateIn { lane_id, snap } => {
                format!("migrate_in {lane_id:?} snap={}", snap.is_some())
            }
            Op::ShutdownDrain => "shutdown_drain".to_string(),
            Op::CreateModel { recipe } => format!("create_model {:?}", recipe),
            Op::DeleteModel { model } => format!("delete_model {model}"),
        };
        format!("{body} deadline={:?} model={:?}", t.1, t.2)
    }

    /// encode → frame-split → decode must reproduce exactly what
    /// `parse_op` yields for the same JSON request text.
    fn assert_parity(line: &str) {
        let req = parse(line).unwrap();
        let frame = encode_request(&req);
        let Framing::Frame { start, end, next } = split_frame(&frame, 0) else {
            panic!("encode produced an incomplete frame for {line}");
        };
        assert_eq!(next, frame.len(), "frame must consume all bytes");
        let bin = decode_request(&frame[start..end]).unwrap();
        let json = parse_op(line).unwrap();
        assert_eq!(sig(&bin), sig(&json), "parity breach for {line}");
    }

    #[test]
    fn every_compact_op_round_trips_bit_exactly() {
        assert_parity(r#"{"op":"ping"}"#);
        assert_parity(r#"{"op":"info"}"#);
        assert_parity(r#"{"op":"reset"}"#);
        assert_parity(r#"{"op":"predict","input":[0.1,-0.25,3e-300]}"#);
        assert_parity(r#"{"op":"predict","input":[]}"#);
        assert_parity(r#"{"op":"stream","input":[1,2,3],"model":7}"#);
        assert_parity(r#"{"op":"train","input":[1,2],"target":[3,4]}"#);
        assert_parity(r#"{"op":"commit"}"#);
        assert_parity(r#"{"op":"commit","alpha":1e-6}"#);
        assert_parity(r#"{"op":"rollback"}"#);
        assert_parity(r#"{"op":"rollback","version":3}"#);
        assert_parity(r#"{"op":"predict","input":[0.5],"deadline_ms":125.5}"#);
        // negative zero must survive the header and payload paths
        assert_parity(r#"{"op":"predict","input":[-0.0,0.0]}"#);
        // subnormals: smallest positive f64
        assert_parity(r#"{"op":"predict","input":[5e-324,-5e-324]}"#);
    }

    #[test]
    fn structured_ops_tunnel_through_parse_op() {
        assert_parity(r#"{"op":"checkpoint"}"#);
        assert_parity(r#"{"op":"migrate"}"#);
        assert_parity(r#"{"op":"migrate","shard":1}"#);
        assert_parity(r#"{"op":"shutdown_drain"}"#);
        assert_parity(r#"{"op":"delete_model","model":42}"#);
        assert_parity(r#"{"op":"create_model","seed":7,"n":16}"#);
        // non-numeric deadline can't ride the header: tunnel must
        // produce the same type error as the JSON parser
        let req = parse(r#"{"op":"predict","input":[1],"deadline_ms":"x"}"#).unwrap();
        let frame = encode_request(&req);
        assert_eq!(frame[4], OP_JSON, "non-numeric field must tunnel");
        let Framing::Frame { start, end, .. } = split_frame(&frame, 0) else {
            panic!("incomplete tunnel frame");
        };
        let err = decode_request(&frame[start..end]).unwrap_err();
        let jerr = parse_op(r#"{"op":"predict","input":[1],"deadline_ms":"x"}"#)
            .unwrap_err();
        assert_eq!(format!("{err:#}"), format!("{jerr:#}"));
    }

    /// Build a compact predict frame by hand at either float width.
    fn raw_predict_frame(vals_f64: &[f64], f32_wide: bool) -> Vec<u8> {
        let mut body = vec![OP_PREDICT, if f32_wide { FLAG_F32 } else { 0 }];
        body.extend_from_slice(&(vals_f64.len() as u32).to_le_bytes());
        for v in vals_f64 {
            if f32_wide {
                body.extend_from_slice(&(*v as f32).to_le_bytes());
            } else {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut frame = Vec::new();
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        frame
    }

    #[test]
    fn special_floats_round_trip_at_both_widths() {
        let specials = [
            f64::NAN,
            0.0,
            -0.0,
            5e-324,                          // smallest f64 subnormal
            f64::MIN_POSITIVE,               // smallest f64 normal
            f32::from_bits(1) as f64,        // smallest f32 subnormal
            f32::MIN_POSITIVE as f64,
            1.0 + f64::EPSILON,
            -1.7976931348623157e308,
        ];
        // f64 width: bits preserved exactly, NaN payload included
        let frame = raw_predict_frame(&specials, false);
        let Framing::Frame { start, end, .. } = split_frame(&frame, 0) else {
            panic!("incomplete frame");
        };
        let (op, _, _) = decode_request(&frame[start..end]).unwrap();
        let Op::Predict(got) = op else { panic!("wrong op") };
        for (g, w) in got.iter().zip(&specials) {
            assert_eq!(g.to_bits(), w.to_bits(), "f64 bits must survive");
        }
        // f32 width: widening is exact for every representable f32
        let f32_specials = [0.0f32, -0.0, f32::from_bits(1), f32::MIN_POSITIVE,
                            f32::NAN, 1.5, -3.25e-40];
        let as64: Vec<f64> = f32_specials.iter().map(|v| *v as f64).collect();
        let frame = raw_predict_frame(&as64, true);
        let Framing::Frame { start, end, .. } = split_frame(&frame, 0) else {
            panic!("incomplete frame");
        };
        let (op, _, _) = decode_request(&frame[start..end]).unwrap();
        let Op::Predict(got) = op else { panic!("wrong op") };
        for (g, w) in got.iter().zip(&f32_specials) {
            if w.is_nan() {
                assert!(g.is_nan());
            } else {
                assert_eq!(g.to_bits(), (*w as f64).to_bits());
            }
        }
    }

    #[test]
    fn torn_and_oversized_frames_are_refused() {
        // torn: a length prefix promising more than the buffer holds
        let mut torn = Vec::new();
        torn.extend_from_slice(&100u32.to_le_bytes());
        torn.push(OP_PING);
        assert!(matches!(split_frame(&torn, 0), Framing::NeedMore));
        // under 4 bytes: not even a length yet
        assert!(matches!(split_frame(&[0x12], 0), Framing::NeedMore));
        // oversized: the length field exceeds the cap — framing lost
        let mut big = Vec::new();
        big.extend_from_slice(&(u32::MAX).to_le_bytes());
        big.push(OP_PING);
        assert!(matches!(split_frame(&big, 0), Framing::Oversized));
        // the blocking reader agrees on all three
        let mut r: &[u8] = &torn;
        assert!(matches!(read_frame(&mut r).unwrap(), ReadFrame::TornEof));
        let mut r: &[u8] = &big;
        assert!(matches!(read_frame(&mut r).unwrap(), ReadFrame::Oversized));
        let mut r: &[u8] = &[];
        assert!(matches!(read_frame(&mut r).unwrap(), ReadFrame::Eof));
        let mut r: &[u8] = &[1, 0];
        assert!(matches!(read_frame(&mut r).unwrap(), ReadFrame::TornEof));
        // and the close-out refusal is the typed bad_frame error
        let refusal = bad_frame_close_frame();
        let Framing::Frame { start, end, .. } = split_frame(&refusal, 0) else {
            panic!("refusal frame incomplete");
        };
        let json = decode_response(&refusal[start..end]).unwrap();
        assert_eq!(json.get("code").and_then(Json::as_str), Some("bad_frame"));
        assert_eq!(json.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn in_body_shape_violations_are_typed_and_survivable() {
        // unknown op byte
        let body = [0xEEu8, 0];
        let e = decode_request(&body).unwrap_err();
        assert_eq!(
            e.downcast_ref::<WireError>().map(|w| w.code),
            Some("bad_frame")
        );
        // truncated payload inside a well-lengthed body
        let mut body = vec![OP_PREDICT, 0];
        body.extend_from_slice(&4u32.to_le_bytes()); // promises 4 floats
        body.extend_from_slice(&1.0f64.to_le_bytes()); // delivers 1
        let e = decode_request(&body).unwrap_err();
        assert_eq!(
            e.downcast_ref::<WireError>().map(|w| w.code),
            Some("bad_frame")
        );
        // trailing junk after the payload
        let mut frame = raw_predict_frame(&[1.0], false);
        let body_start = 4;
        let mut body = frame.split_off(body_start);
        body.push(0xAB);
        let e = decode_request(&body).unwrap_err();
        assert_eq!(
            e.downcast_ref::<WireError>().map(|w| w.code),
            Some("bad_frame")
        );
        // semantic violation keeps the JSON parser's message verbatim
        let mut body = vec![OP_TRAIN, 0];
        body.extend_from_slice(&((MAX_TRAIN_ROWS_PER_OP + 1) as u32).to_le_bytes());
        let e = decode_request(&body).unwrap_err();
        assert!(
            format!("{e}").contains("train op too large"),
            "row-cap message must match the JSON parser: {e}"
        );
    }

    #[test]
    fn responses_round_trip_structurally() {
        let cases = [
            Json::obj(vec![("ok", Json::Bool(true))]),
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("output", Json::Arr(vec![Json::Num(0.1), Json::Num(-0.0)])),
            ]),
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("output", Json::Arr(vec![Json::Num(5e-324)])),
                ("steps_per_sec", Json::Num(123456.789)),
            ]),
            Json::obj(vec![("ok", Json::Bool(true)), ("rows", Json::Num(42.0))]),
            Json::obj(vec![("ok", Json::Bool(true)), ("version", Json::Num(7.0))]),
            Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str("no lane".into())),
                ("code", Json::Str("no_lane".into())),
            ]),
            Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str("moved".into())),
                ("code", Json::Str("moved".into())),
                ("addr", Json::Str("10.0.0.2:4100".into())),
            ]),
            // structured fallback: an info-shaped response
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("n", Json::Num(30.0)),
                ("shards", Json::Num(2.0)),
                ("precision", Json::Str("f64".into())),
            ]),
        ];
        for resp in &cases {
            let mut frame = Vec::new();
            encode_response(resp, &mut frame);
            let Framing::Frame { start, end, next } = split_frame(&frame, 0) else {
                panic!("incomplete response frame");
            };
            assert_eq!(next, frame.len());
            let back = decode_response(&frame[start..end]).unwrap();
            assert_eq!(&back, resp, "response must survive structurally");
        }
    }

    #[test]
    fn response_floats_are_bit_exact_not_formatted() {
        // a value whose shortest decimal round-trip is long — the binary
        // path must carry the BITS, no Display involved
        let v = 0.1f64 + 0.2f64;
        let resp = Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("output", Json::Arr(vec![Json::Num(v)])),
        ]);
        let mut frame = Vec::new();
        encode_response(&resp, &mut frame);
        // the raw bits must appear verbatim in the frame
        let needle = v.to_le_bytes();
        assert!(
            frame.windows(8).any(|w| w == needle),
            "payload must carry raw LE bits"
        );
        let Framing::Frame { start, end, .. } = split_frame(&frame, 0) else {
            panic!("incomplete frame");
        };
        let back = decode_response(&frame[start..end]).unwrap();
        let Some(Json::Num(got)) =
            back.get("output").and_then(Json::as_arr).and_then(|a| a.first()).cloned()
        else {
            panic!("output missing");
        };
        assert_eq!(got.to_bits(), v.to_bits());
    }

    #[test]
    fn hello_shapes_are_fixed() {
        assert_eq!(client_hello().len(), HELLO_LEN);
        assert_eq!(server_hello().len(), HELLO_LEN);
        assert_eq!(&client_hello()[..4], &MAGIC);
        assert_eq!(&server_hello()[..4], &MAGIC);
        assert_eq!(client_hello()[4], VERSION);
        assert_ne!(client_hello(), server_hello(), "ack must be distinguishable");
    }
}
