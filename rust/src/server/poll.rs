//! Event-driven serving transport: a hand-rolled epoll readiness loop.
//!
//! The thread-per-connection transport burns one OS thread per client
//! just to park in `read_line` — at 10k idle streaming connections that
//! is 10k stacks and 10k scheduler entries doing nothing. The paper's
//! O(N) step makes the arithmetic cheap enough that those threads ARE
//! the serving cost. This module replaces them with P poll threads
//! (`--poll-threads`, default 1 — bit-identical to the historical
//! single-thread loop). Thread 0 owns the listener and deals accepted
//! sockets round-robin through per-worker hand-off rings; each thread
//! then owns its dealt connections outright — read/write buffers, slot
//! queue, idle wheel, completion eventfd — so no per-connection state is
//! ever shared. Per-connection wire format is negotiated on the first
//! bytes: anything that diverges from the `LRBF` magic is the unchanged
//! line-delimited JSON protocol; a completed 8-byte hello upgrades the
//! connection to length-prefixed binary frames (`binframe`) with raw LE
//! float payloads — same ops, same typed error codes, no float
//! formatting on either side. One thread's loop:
//!
//! ```text
//!             ┌─────────────────────────────────────────────────────┐
//!             │                  poll thread (epoll)                │
//!  listener ──┤ accept → register fd (non-blocking, level-trig.)    │
//!  conn fd ───┤ readable → rbuf → line frame → dispatch:            │
//!             │    info / errors / hub-less stream → Ready slot     │
//!             │    predict/stream/reset → Waiting slot + EventReply │
//!             │                    │ submit ───────────▶ shard queues
//!             │                    ▼                        │ sweep
//!  eventfd ◀──┼──────── CompletionQueue.push ◀── ReplySender┘
//!             │ wake → drain completions → resolve slot → wbuf      │
//!             │ writable → flush wbuf (EPOLLOUT only while pending) │
//!             └─────────────────────────────────────────────────────┘
//! ```
//!
//! Raw `libc` syscalls via `extern "C"` — `epoll_create1` / `epoll_ctl`
//! / `epoll_wait`, plus an `eventfd` the sweepers signal when they
//! complete a job (std links libc on Linux; no new crates). Sockets stay
//! `std::net` types flipped to non-blocking.
//!
//! Invariants:
//!
//! * **FIFO responses.** Each connection keeps an ordered slot queue;
//!   a response is flushed only when every earlier request's slot is
//!   resolved, so pipelined clients see replies in request order even
//!   though shard queues complete out of order.
//! * **Exactly-one completion.** Every queued job carries an
//!   [`EventReply`] whose `Drop` delivers a `Dropped` completion if the
//!   sweeper dies or refuses the job — a pending slot can never leak, so
//!   the loop registers slots unconditionally and handles fallbacks
//!   (direct predict / error response) at completion time.
//! * **Same decision tree as the threaded path.** `dispatch` mirrors
//!   `wire.rs::handle_request` op for op on the shared transport-
//!   agnostic core, so responses are bit-identical between transports
//!   (tested in `wire.rs` and `rust/tests/pipeline.rs`).
//! * **Thread-free idle.** An idle connection costs one fd and one
//!   `Conn` entry. The box runs S sweepers + 1 poll thread regardless
//!   of connection count (asserted in `rust/tests/pipeline.rs`).
//!
//! Hub-overflow streaming (beyond `S × 64` lanes) runs its
//! connection-local fallback inline on the poll thread — O(T·N) per
//! request of the same bit-identical arithmetic; acceptable because
//! overflow lanes are the degraded tier by definition.

#![cfg(target_os = "linux")]

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::raw::{c_int, c_void};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::util::json::Json;

use super::binframe;
use super::front::{Completion, CompletionQueue, EventReply, Reply, ReplySender};
use super::registry::{ModelId, BASE_MODEL};
use super::shard::{LaneBinding, PollStats, ShardedFront};
use super::wire::{
    bind_conn_model, checkpoint_response, coded_error, error_response,
    fallback_key, guard_streamable, guard_train_rows, handle_create_model,
    handle_delete_model, handle_migrate, handle_migrate_in,
    hub_full_train_error, info_response, ip_key, no_lane_error,
    nothing_to_commit_error, ok_response, ownership_guard, parse_op,
    pong_response, predict_response, stream_fallback, stream_response,
    train_response, try_acquire_lane, unavailable_error, version_response,
    ConnState, DrainCfg, Op, SIGTERM_DRAIN,
};

// ---------------------------------------------------------------------------
// raw syscall surface (glibc symbols; std already links libc on Linux)
// ---------------------------------------------------------------------------

/// Kernel epoll event record. On x86-64 the kernel ABI packs this struct
/// (no padding between `events` and `data`); elsewhere it is naturally
/// aligned. Fields are only ever read BY VALUE (taking a reference to a
/// packed field would be UB).
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout_ms: c_int,
    ) -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    /// `accept4(2)`: accept + O_NONBLOCK + CLOEXEC in ONE syscall — the
    /// std `accept` path costs an extra `fcntl` round trip per
    /// connection to flip non-blocking. `addrlen` is `socklen_t` (u32 on
    /// Linux); the peer address lands in `addr` as a raw sockaddr.
    fn accept4(
        sockfd: c_int,
        addr: *mut c_void,
        addrlen: *mut u32,
        flags: c_int,
    ) -> c_int;
    #[link_name = "read"]
    fn c_read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    #[link_name = "write"]
    fn c_write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    #[link_name = "close"]
    fn c_close(fd: c_int) -> c_int;
}

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;
// SOCK_* accept4 flags share the O_* octal values on Linux
const SOCK_NONBLOCK: c_int = 0o4000;
const SOCK_CLOEXEC: c_int = 0o2000000;
const AF_INET: u16 = 2;
const AF_INET6: u16 = 10;
const EINTR: i32 = 4;
const ENOMEM: i32 = 12;
const ENFILE: i32 = 23;
const EMFILE: i32 = 24;
const ENOSYS: i32 = 38;
const EPROTO: i32 = 71;
const ECONNABORTED: i32 = 103;
const ENOBUFS: i32 = 105;

/// Thin RAII epoll instance.
struct Epoll {
    fd: c_int,
}

impl Epoll {
    fn new() -> Result<Self> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        anyhow::ensure!(
            fd >= 0,
            "epoll_create1: {}",
            std::io::Error::last_os_error()
        );
        Ok(Self { fd })
    }

    fn ctl(&self, op: c_int, fd: c_int, events: u32, token: u64) -> Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
        anyhow::ensure!(rc == 0, "epoll_ctl: {}", std::io::Error::last_os_error());
        Ok(())
    }

    fn add(&self, fd: c_int, events: u32, token: u64) -> Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    fn modify(&self, fd: c_int, events: u32, token: u64) -> Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    fn del(&self, fd: c_int) {
        // failure only means the fd is already gone — nothing to unwind
        let _ = self.ctl(EPOLL_CTL_DEL, fd, 0, 0);
    }

    /// Block until at least one event is ready or `timeout_ms` elapses
    /// (`-1` = forever; `Ok(0)` = timed out). EINTR surfaces as `Ok(0)`
    /// rather than retrying in place: a signal (SIGTERM → drain) must
    /// bounce control back to the loop head so the drain flag is seen
    /// even mid-`epoll_wait`.
    fn wait(&self, events: &mut [EpollEvent], timeout_ms: c_int) -> Result<usize> {
        let n = unsafe {
            epoll_wait(
                self.fd,
                events.as_mut_ptr(),
                events.len() as c_int,
                timeout_ms,
            )
        };
        if n >= 0 {
            return Ok(n as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.raw_os_error() == Some(EINTR) {
            return Ok(0);
        }
        Err(anyhow!("epoll_wait: {err}"))
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            c_close(self.fd);
        }
    }
}

/// The sweeper→poll wake channel: sweeper threads `signal()` after
/// pushing a completion, the poll thread `drain_counter()`s on
/// readability. The counter semantics coalesce any number of signals
/// into one readable event — exactly what a batch drain wants.
struct EventFd {
    fd: c_int,
}

impl EventFd {
    fn new() -> Result<Self> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        anyhow::ensure!(fd >= 0, "eventfd: {}", std::io::Error::last_os_error());
        Ok(Self { fd })
    }

    fn signal(&self) {
        let one: u64 = 1;
        // EAGAIN (counter saturated) still leaves the fd readable, so a
        // lost increment cannot lose the wake
        let _ = unsafe { c_write(self.fd, &one as *const u64 as *const c_void, 8) };
    }

    fn drain_counter(&self) {
        let mut v: u64 = 0;
        let _ = unsafe { c_read(self.fd, &mut v as *mut u64 as *mut c_void, 8) };
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe {
            c_close(self.fd);
        }
    }
}

// ---------------------------------------------------------------------------
// connection table
// ---------------------------------------------------------------------------

const LISTENER_TOKEN: u64 = u64::MAX;
const WAKE_TOKEN: u64 = u64::MAX - 1;
/// A single request line longer than this is not protocol traffic; the
/// connection is dropped instead of buffering it unboundedly. Complete
/// lines are framed out of the buffer every readiness round, so the
/// buffer only approaches this bound when one LINE does.
const MAX_LINE_BYTES: usize = 64 << 20;
/// Max bytes read from one connection per readiness round, PROCESS-wide:
/// level-triggered epoll re-delivers whatever is left, so a firehose
/// client yields its poll thread to its peers every budget-slice bytes
/// instead of monopolizing the loop until its socket runs dry. With P
/// poll threads each thread's slice is `READ_BUDGET / P` (floored at
/// 16 KiB) — P threads must not multiply the process read budget.
const READ_BUDGET: usize = 256 << 10;
/// Floor for one poll thread's per-round read slice.
const READ_BUDGET_FLOOR: usize = 16 << 10;
/// Shared write-buffer budget for the whole event-loop transport: the
/// per-connection backpressure high-water mark is this budget
/// apportioned across the live connections (see [`wbuf_high_water`]), so
/// worst-case unflushed-response memory is bounded per PROCESS, not per
/// connection. With P poll threads each thread apportions a
/// `WBUF_TOTAL_BUDGET / P` slice (floored at 1 MiB) over ITS live
/// connections — again so P threads don't multiply the process budget.
const WBUF_TOTAL_BUDGET: usize = 64 << 20;
/// Floor for one poll thread's write-buffer budget slice.
const WBUF_BUDGET_FLOOR: usize = 1 << 20;
/// Write-side backpressure threshold for one connection: while more than
/// this many unflushed response bytes are pending, the loop stops
/// reading from it (EPOLLIN dropped), so a client that pipelines
/// requests without ever draining replies throttles ITSELF instead of
/// growing server memory — the event-loop analogue of the threaded path
/// blocking in `write_all`.
///
/// The mark adapts to load: `total_budget / live` clamped to
/// [64 KiB, 1 MiB], where `total_budget` is the calling poll thread's
/// slice of [`WBUF_TOTAL_BUDGET`]. At one poll thread (the default) up
/// to 64 connections each get the old fixed 1 MiB; past that the shared
/// budget divides down to a 64 KiB floor (≈ one max-size pipelined burst
/// of replies), so 10k slow-draining clients pin ~640 MB in the old
/// scheme but ≤ 64 MiB + one response each here.
fn wbuf_high_water(total_budget: usize, live: usize) -> usize {
    (total_budget / live.max(1)).clamp(64 << 10, 1 << 20)
}
/// Events drained per `epoll_wait` round.
const EVENT_BATCH: usize = 128;

/// What an in-flight (queued-to-a-sweeper) request resolves into.
enum PendingKind {
    /// The input is kept (shared with the queued job via `Arc` — no
    /// copy) so a `Dropped` completion (sweeper gone) can fall back to
    /// the direct same-precision `Model::predict`, exactly like
    /// `BatchFront::predict` does on the threaded path.
    Predict {
        /// Model the request was stamped with at submit — the dropped-
        /// completion fallback must compute with THIS model's planes,
        /// not the base model's.
        model: ModelId,
        input: Arc<Vec<f64>>,
        queued_at: Instant,
    },
    Stream,
    Train,
    Commit,
    Rollback,
    Checkpoint,
    Restore,
    Reset,
}

/// One response slot in a connection's FIFO: resolved (`Ready`) slots at
/// the head flush to the socket; a `Waiting` head holds every later
/// response back so pipelined replies stay in request order.
enum Slot {
    Ready(Json),
    Waiting { token: u64, kind: PendingKind },
}

/// Per-connection wire codec, decided by the connection's first bytes
/// (see `wire.rs` — the threaded transport negotiates identically).
#[derive(Clone, Copy, PartialEq)]
enum Codec {
    /// Still sniffing: the bytes so far are a proper prefix of the
    /// binary hello. No request is parsed in this state.
    Probe,
    /// Line-delimited JSON (the default — first byte diverged from the
    /// magic, which any JSON request's `{` does immediately).
    Json,
    /// Negotiated length-prefixed binary frames.
    Binary,
}

struct Conn {
    sock: TcpStream,
    state: ConnState,
    codec: Codec,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    /// Bytes of `wbuf` already written to the socket.
    wpos: usize,
    slots: VecDeque<Slot>,
    /// Last epoll interest mask registered for this fd.
    interest: u32,
    /// Whether the fd is currently registered with epoll. Deregistered
    /// while the wanted mask is empty (EOF seen, nothing to write,
    /// waiting only on sweeper completions): EPOLLHUP/EPOLLERR are
    /// unmaskable and level-triggered, so a fully-closed peer would
    /// busy-wake the loop through an empty interest mask otherwise.
    registered: bool,
    /// Peer sent EOF: serve out pending slots, flush, then close.
    eof: bool,
    /// Hard error: close as soon as observed.
    dead: bool,
    /// Last instant of request-reply activity: stamped when bytes arrive
    /// from the peer AND when a reply flushes to it (so the server's own
    /// queue/sweep latency never counts as client silence). The
    /// idle-timeout wheel reaps `idle_timeout` after the LATER of the
    /// client's last bytes and our last flushed response.
    last_active: Instant,
}

impl Conn {
    fn finished(&self) -> bool {
        self.dead || (self.eof && self.slots.is_empty() && self.wpos >= self.wbuf.len())
    }
}

// ---------------------------------------------------------------------------
// idle-timeout timer wheel
// ---------------------------------------------------------------------------

/// Coarse timer wheel reaping connections that have gone silent for the
/// configured idle timeout.
///
/// Design: O(1) amortized, LAZY repositioning. Each live connection sits
/// in exactly one slot (placed at registration, and re-placed only when
/// its slot comes due). Activity does NOT move the entry — `conn_event`
/// just stamps `last_active`, and when the slot fires the wheel checks
/// the stamp: still fresh → re-insert at the remaining time; genuinely
/// idle → reap. So the per-request cost of the timeout is one `Instant`
/// store, and the wheel only does work once per timeout period per
/// connection. The tick is `timeout/8` (≥ 25 ms): reaping happens within
/// ~12% of the configured timeout, which is all "reap silent
/// connections" needs.
struct IdleWheel {
    slots: Vec<Vec<u64>>,
    cur: usize,
    tick: Duration,
    timeout: Duration,
    next_tick: Instant,
}

impl IdleWheel {
    fn new(timeout: Duration, now: Instant) -> Self {
        let timeout = timeout.max(Duration::from_millis(1));
        let tick = (timeout / 8).max(Duration::from_millis(25));
        // enough slots to place a full timeout ahead of `cur`
        let n = (timeout.as_micros() / tick.as_micros()) as usize + 2;
        Self {
            slots: vec![Vec::new(); n],
            cur: 0,
            tick,
            timeout,
            next_tick: now + tick,
        }
    }

    /// Place `id` so its slot fires no earlier than `remaining` from now
    /// (rounded UP to a tick — firing early would reap live connections).
    fn schedule(&mut self, id: u64, remaining: Duration) {
        let n = self.slots.len();
        let ticks = ((remaining.as_micros() / self.tick.as_micros()) as usize + 1)
            .min(n - 1);
        let slot = (self.cur + ticks) % n;
        self.slots[slot].push(id);
    }

    /// Drain every slot that has come due by `now`. The caller checks
    /// each id's `last_active` and either reaps or re-schedules it.
    fn expired(&mut self, now: Instant) -> Vec<u64> {
        let mut due = Vec::new();
        while now >= self.next_tick {
            self.cur = (self.cur + 1) % self.slots.len();
            due.append(&mut self.slots[self.cur]);
            self.next_tick += self.tick;
        }
        due
    }

    /// Milliseconds until the next tick boundary — the epoll timeout that
    /// keeps the wheel advancing while the loop is otherwise idle.
    /// Clamped to [1 ms, 60 s]: `as_millis()` is u128, and a huge
    /// configured timeout must not wrap the `c_int` negative (which would
    /// degrade the idle loop into a busy poll); waking at most once a
    /// minute costs nothing and `expired()` is driven by real time, so an
    /// early wake never mis-fires a slot.
    fn timeout_ms(&self, now: Instant) -> c_int {
        let ms = self
            .next_tick
            .saturating_duration_since(now)
            .as_millis()
            .min(60_000) as c_int;
        ms.max(1)
    }
}

// ---------------------------------------------------------------------------
// the loop
// ---------------------------------------------------------------------------

/// State shared by the P poll threads of one event-loop transport.
/// Thread 0 owns the listener and deals accepted sockets; workers own
/// everything about their dealt connections (buffers, slots, wheel,
/// completion eventfd) — nothing per-connection is ever shared, so the
/// P-thread loop preserves every single-owner invariant of the P=1 loop.
struct PollShared {
    /// No more connections will EVER be dealt (max reached, drain, or
    /// accept death): a worker whose table empties may exit.
    accept_done: AtomicBool,
    /// Graceful drain requested anywhere (op on any thread's conn, or
    /// SIGTERM): every thread flips its own conns to serve-out mode.
    draining: AtomicBool,
    /// Per-thread liveness; the dealer skips dead threads.
    alive: Vec<AtomicBool>,
    /// `info` observability: per-thread rounds + binary upgrades.
    stats: Arc<PollStats>,
    /// Hand-off rings: thread 0 pushes `(socket, key)`, the owning
    /// worker drains at its next wake (ring `0` stays empty — thread 0
    /// registers its own share directly).
    rings: Vec<Mutex<VecDeque<(TcpStream, u64)>>>,
    /// Every thread's wake eventfd (same fd its CompletionQueue
    /// signals): ring hand-offs and cross-thread flag flips wake
    /// through here.
    wakes: Vec<Arc<EventFd>>,
    /// Lane bindings retained by connections that closed while
    /// draining, merged from every thread, spilled once after join.
    drain_keep: Mutex<Vec<Arc<LaneBinding>>>,
}

impl PollShared {
    fn wake_all(&self) {
        for w in &self.wakes {
            w.signal();
        }
    }
}

struct EventLoop {
    ep: Epoll,
    wake: Arc<EventFd>,
    completions: Arc<CompletionQueue>,
    front: Arc<ShardedFront>,
    conns: HashMap<u64, Conn>,
    /// In-flight reply token → owning connection id.
    token_conn: HashMap<u64, u64>,
    next_conn_id: u64,
    next_token: u64,
    accepted: usize,
    accepting: bool,
    max_conns: Option<usize>,
    /// Idle-connection reaper; `None` = connections may idle forever.
    wheel: Option<IdleWheel>,
    /// Graceful drain requested (`shutdown_drain` op or SIGTERM): stop
    /// accepting, serve out in-flight slots, flush, close.
    draining: bool,
    /// One-shot guard: live connections have been flipped to EOF-serve-
    /// out mode for the drain.
    drain_closed: bool,
    /// Lane bindings retained (NOT released) by connections that closed
    /// while draining, so their lanes survive to be spilled.
    drain_keep: Vec<Arc<LaneBinding>>,
    /// This thread's index in the poll-thread group (0 = the acceptor).
    thread_idx: usize,
    /// Poll-thread count P (1 = the classic single-owner loop).
    threads: usize,
    /// This thread's slice of the process per-round read budget.
    read_budget: usize,
    /// This thread's slice of the process write-buffer budget.
    wbuf_budget: usize,
    shared: Arc<PollShared>,
}

/// Serve every connection of `listener` across `poll_threads` epoll
/// threads. Returns once `max_conns` connections have been accepted AND
/// have all closed (`None`: runs forever), or after a graceful drain
/// (`shutdown_drain` op, or SIGTERM when `drain.watch_sigterm`) has
/// served out every in-flight request. Connections silent for
/// `idle_timeout` are reaped by a coarse per-thread timer wheel (`None`
/// = never). Called by [`super::wire::serve_on_opts`], which owns the
/// sweeper lifecycle.
///
/// `poll_threads == 1` runs the whole loop on the calling thread,
/// bit-identically to the historical single-owner transport. With P > 1
/// the calling thread (thread 0) owns the listener and deals accepted
/// sockets round-robin — its own share registered directly, the rest
/// handed off through per-worker rings — while every other aspect of a
/// connection's life stays single-owner on its dealt thread.
pub(crate) fn serve_event_loop(
    listener: TcpListener,
    front: Arc<ShardedFront>,
    max_conns: Option<usize>,
    idle_timeout: Option<Duration>,
    drain: &DrainCfg,
    poll_threads: usize,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let threads = poll_threads.max(1);
    let wakes = (0..threads)
        .map(|_| EventFd::new().map(Arc::new))
        .collect::<Result<Vec<_>>>()?;
    let shared = Arc::new(PollShared {
        accept_done: AtomicBool::new(false),
        draining: AtomicBool::new(false),
        alive: (0..threads).map(|_| AtomicBool::new(true)).collect(),
        stats: Arc::new(PollStats::new(threads)),
        rings: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
        wakes: wakes.clone(),
        drain_keep: Mutex::new(Vec::new()),
    });
    front.set_poll_stats(Arc::clone(&shared.stats));
    let mut workers = Vec::new();
    for t in 1..threads {
        let front = Arc::clone(&front);
        let shared = Arc::clone(&shared);
        let wake = Arc::clone(&wakes[t]);
        let watch_sigterm = drain.watch_sigterm;
        workers.push(
            std::thread::Builder::new()
                .name(format!("lr-poll-{t}"))
                .spawn(move || {
                    let r = run_poll_thread(
                        t,
                        threads,
                        None,
                        front,
                        None,
                        idle_timeout,
                        watch_sigterm,
                        Arc::clone(&shared),
                        wake,
                    );
                    if let Err(e) = &r {
                        eprintln!("poll thread {t} died: {e:#}");
                    }
                    shared.alive[t].store(false, Ordering::SeqCst);
                    r
                })?,
        );
    }
    let result = run_poll_thread(
        0,
        threads,
        Some(&listener),
        Arc::clone(&front),
        max_conns,
        idle_timeout,
        drain.watch_sigterm,
        Arc::clone(&shared),
        Arc::clone(&wakes[0]),
    );
    // thread 0 is done accepting forever; release the workers
    shared.accept_done.store(true, Ordering::SeqCst);
    shared.alive[0].store(false, Ordering::SeqCst);
    shared.wake_all();
    let mut worker_err: Option<anyhow::Error> = None;
    for w in workers {
        match w.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => worker_err = worker_err.or(Some(e)),
            Err(_) => {
                worker_err =
                    worker_err.or_else(|| Some(anyhow!("poll thread panicked")));
            }
        }
    }
    // spill the lanes retained by drained connections, then free them
    let keep = std::mem::take(&mut *shared.drain_keep.lock().unwrap());
    if let Some(dir) = &drain.spill_dir {
        if !keep.is_empty() {
            let n = front.spill_bindings(&keep, dir);
            eprintln!(
                "drain-checkpoint: spilled {n} lane(s) to {}",
                dir.display()
            );
        }
    }
    for b in &keep {
        front.release_binding(b);
    }
    result.and(match worker_err {
        Some(e) => Err(e),
        None => Ok(()),
    })
}

/// One poll thread's readiness loop — thread 0 runs it with the
/// listener, workers without. Structurally identical to the historical
/// single-thread loop; the multi-thread additions are the shared-flag
/// observation at the loop head, the hand-off ring drain on wake, and
/// the budget slices.
#[allow(clippy::too_many_arguments)]
fn run_poll_thread(
    thread_idx: usize,
    threads: usize,
    listener: Option<&TcpListener>,
    front: Arc<ShardedFront>,
    max_conns: Option<usize>,
    idle_timeout: Option<Duration>,
    watch_sigterm: bool,
    shared: Arc<PollShared>,
    wake: Arc<EventFd>,
) -> Result<()> {
    let ep = Epoll::new()?;
    let completions = {
        let w = Arc::clone(&wake);
        CompletionQueue::new(Box::new(move || w.signal()))
    };
    if let Some(l) = listener {
        ep.add(l.as_raw_fd(), EPOLLIN, LISTENER_TOKEN)?;
    }
    ep.add(wake.fd, EPOLLIN, WAKE_TOKEN)?;
    let mut lp = EventLoop {
        ep,
        wake,
        completions,
        front,
        conns: HashMap::new(),
        token_conn: HashMap::new(),
        next_conn_id: 0,
        next_token: 0,
        accepted: 0,
        accepting: listener.is_some(),
        max_conns,
        wheel: idle_timeout.map(|t| IdleWheel::new(t, Instant::now())),
        draining: false,
        drain_closed: false,
        drain_keep: Vec::new(),
        thread_idx,
        threads,
        read_budget: (READ_BUDGET / threads).max(READ_BUDGET_FLOOR),
        wbuf_budget: (WBUF_TOTAL_BUDGET / threads).max(WBUF_BUDGET_FLOOR),
        shared,
    };
    let mut events = vec![EpollEvent { events: 0, data: 0 }; EVENT_BATCH];
    let mut accept_err: Option<anyhow::Error> = None;
    loop {
        if super::fault::poll_thread_kill(lp.thread_idx) {
            lp.kill_self(listener);
            return Ok(());
        }
        if watch_sigterm && SIGTERM_DRAIN.load(Ordering::SeqCst) {
            lp.draining = true;
        }
        if lp.shared.draining.load(Ordering::SeqCst) {
            lp.draining = true;
        }
        if lp.draining {
            // first observer publishes the drain and wakes the group so
            // a worker parked in epoll_wait sees it promptly
            if !lp.shared.draining.swap(true, Ordering::SeqCst) {
                lp.shared.wake_all();
            }
            if let Some(l) = listener {
                lp.stop_accepting(l);
            }
            lp.drain_conns();
        }
        if let Some(max) = lp.max_conns {
            if lp.accepting && lp.accepted >= max {
                lp.stop_accepting(listener.expect("max_conns on acceptor"));
            }
        }
        // ring hand-offs are drained at the loop head as well as on
        // wake: a worker must adopt every dealt socket before it can
        // decide its table is empty
        lp.drain_handoff();
        let done_feeding = if lp.thread_idx == 0 {
            !lp.accepting
        } else {
            lp.shared.accept_done.load(Ordering::SeqCst)
        };
        if done_feeding && lp.conns.is_empty() {
            break;
        }
        // with a wheel, wake at the next tick boundary so idle reaping
        // advances even when no fd is active (n = 0 on timeout); a
        // SIGTERM watcher bounds the sleep so the drain flag is seen
        // promptly even if the signal lands on another thread
        let mut timeout_ms = lp
            .wheel
            .as_ref()
            .map_or(-1, |w| w.timeout_ms(Instant::now()));
        if watch_sigterm {
            timeout_ms = if timeout_ms < 0 { 250 } else { timeout_ms.min(250) };
        }
        let n = lp.ep.wait(&mut events, timeout_ms)?;
        lp.shared.stats.bump_round(lp.thread_idx);
        for ev in &events[..n] {
            // copy packed fields by value (references into a packed
            // struct would be UB)
            let (token, mask) = (ev.data, ev.events);
            match token {
                LISTENER_TOKEN => {
                    let l = listener.expect("listener event on acceptor");
                    if let Err(e) = lp.accept_ready(l) {
                        // like the threaded path: stop accepting, serve
                        // the live connections out, then surface the
                        // accept error
                        lp.stop_accepting(l);
                        accept_err = Some(e);
                    }
                }
                WAKE_TOKEN => {
                    lp.wake.drain_counter();
                    lp.drain_handoff();
                    lp.deliver_completions();
                }
                id => lp.conn_event(id, mask),
            }
        }
        lp.reap_idle();
    }
    // merge this thread's drain-retained lanes for the post-join spill
    if !lp.drain_keep.is_empty() {
        lp.shared
            .drain_keep
            .lock()
            .unwrap()
            .append(&mut lp.drain_keep);
    }
    match accept_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

impl EventLoop {
    fn stop_accepting(&mut self, listener: &TcpListener) {
        if self.accepting {
            self.accepting = false;
            self.ep.del(listener.as_raw_fd());
            // no socket will ever be dealt again: workers whose tables
            // empty may exit, and any idle ones should notice now
            self.shared.accept_done.store(true, Ordering::SeqCst);
            self.shared.wake_all();
        }
    }

    /// Adopt every connection dealt to this thread's hand-off ring.
    fn drain_handoff(&mut self) {
        loop {
            let next = self.shared.rings[self.thread_idx]
                .lock()
                .unwrap()
                .pop_front();
            let Some((sock, key)) = next else {
                return;
            };
            // a connection that can't be registered is dropped (closed),
            // never fatal to the serving loop
            let _ = self.register_conn(sock, key);
        }
    }

    /// Fault-injected death of this poll thread: every owned connection
    /// is answered with the typed `unavailable` refusal (pending slots
    /// included — their sweeper completions will find no owner) and
    /// closed, then the thread exits. Sibling poll threads, sweepers,
    /// and the other threads' connections are untouched.
    fn kill_self(&mut self, listener: Option<&TcpListener>) {
        if let Some(l) = listener {
            self.stop_accepting(l);
        }
        self.shared.alive[self.thread_idx].store(false, Ordering::SeqCst);
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            let Some(mut conn) = self.conns.remove(&id) else {
                continue;
            };
            for s in conn.slots.iter_mut() {
                if matches!(s, Slot::Waiting { .. }) {
                    *s = Slot::Ready(error_response(&unavailable_error()));
                }
            }
            conn.slots
                .push_back(Slot::Ready(error_response(&unavailable_error())));
            conn.eof = true;
            self.pump(&mut conn, id);
            // best-effort single flush; close regardless (the thread is
            // dying — a slow reader doesn't get to keep it alive)
            conn.dead = true;
            self.finish_or_keep(id, conn);
        }
        eprintln!(
            "fault-inject: poll thread {} killed ({} sibling thread(s) \
             keep serving)",
            self.thread_idx,
            self.threads - 1
        );
    }

    /// One-shot drain propagation: flip every live connection to EOF
    /// mode (stop reading; in-flight slots still resolve and flush —
    /// never a mid-reply cutoff) and close the ones that are already
    /// quiescent. Idempotent via `drain_closed`.
    fn drain_conns(&mut self) {
        if self.drain_closed {
            return;
        }
        self.drain_closed = true;
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            let Some(mut conn) = self.conns.remove(&id) else {
                continue;
            };
            conn.eof = true;
            self.pump(&mut conn, id);
            self.finish_or_keep(id, conn);
        }
    }

    /// Drain the accept backlog (level-triggered: whatever is left stays
    /// readable for the next round). Each accept is one
    /// `accept4(SOCK_NONBLOCK | SOCK_CLOEXEC)` syscall — no per-accept
    /// `fcntl` — with a runtime fallback to `accept` + `set_nonblocking`
    /// the first time the kernel reports ENOSYS.
    fn accept_ready(&mut self, listener: &TcpListener) -> Result<()> {
        loop {
            if let Some(max) = self.max_conns {
                if self.accepted >= max {
                    return Ok(()); // the loop head deregisters next round
                }
            }
            match accept_nonblocking(listener) {
                Ok((sock, peer)) => {
                    // same key derivation as the threaded path: peer IP,
                    // so reconnects keep their home shard; a peer address
                    // the kernel didn't hand back (or in an unknown
                    // family) gets the tagged fallback key, disjoint from
                    // the IPv4 key space
                    let key = peer
                        .map(|ip| ip_key(&ip))
                        .unwrap_or_else(|| fallback_key(self.accepted));
                    let t = self.pick_thread();
                    self.accepted += 1;
                    if t == self.thread_idx {
                        // a connection that can't be registered is
                        // dropped (closed), never fatal to the serving
                        // loop
                        let _ = self.register_conn(sock, key);
                    } else {
                        // deal to a sibling poll thread: push + wake; it
                        // adopts the socket in drain_handoff
                        self.shared.rings[t].lock().unwrap().push_back((sock, key));
                        self.shared.wakes[t].signal();
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => match e.raw_os_error() {
                    // the pending connection was RST before accept —
                    // it is consumed; keep draining the backlog
                    Some(ECONNABORTED) | Some(EPROTO) => continue,
                    // resource exhaustion (fd table full, no buffers):
                    // not this listener's death sentence — yield the
                    // round with a brief throttle (the level-triggered
                    // listener would otherwise busy-spin while the
                    // condition persists) and retry on the next wake
                    Some(EMFILE) | Some(ENFILE) | Some(ENOBUFS) | Some(ENOMEM) => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        return Ok(());
                    }
                    _ => return Err(e.into()),
                },
            }
        }
    }

    /// Round-robin deal target for the next accepted socket, skipping
    /// dead poll threads (falls back to this thread — the acceptor never
    /// marks itself dead while accepting).
    fn pick_thread(&self) -> usize {
        for off in 0..self.threads {
            let t = (self.accepted + off) % self.threads;
            if self.shared.alive[t].load(Ordering::SeqCst) {
                return t;
            }
        }
        self.thread_idx
    }

    /// Register an accepted, ALREADY-non-blocking socket (the accept path
    /// flips it via `accept4(SOCK_NONBLOCK)` or the fallback `fcntl`).
    fn register_conn(&mut self, sock: TcpStream, key: u64) -> Result<()> {
        let id = self.next_conn_id;
        self.next_conn_id += 1;
        let interest = EPOLLIN | EPOLLRDHUP;
        self.ep.add(sock.as_raw_fd(), interest, id)?;
        let now = Instant::now();
        if let Some(wheel) = &mut self.wheel {
            wheel.schedule(id, wheel.timeout);
        }
        let mut state = ConnState::new(key, self.front.shard_for_key(key));
        state.poll_thread = Some(self.thread_idx);
        self.conns.insert(
            id,
            Conn {
                sock,
                state,
                codec: Codec::Probe,
                rbuf: Vec::new(),
                wbuf: Vec::new(),
                wpos: 0,
                slots: VecDeque::new(),
                interest,
                registered: true,
                eof: false,
                dead: false,
                last_active: now,
            },
        );
        Ok(())
    }

    /// Advance the idle wheel: reap connections silent past the timeout,
    /// re-schedule the rest at their remaining time. A connection with an
    /// in-flight request or an unflushed response is never reaped — only
    /// genuinely quiescent peers are (a slow sweep or a slow-draining
    /// client is backpressure's problem, not the reaper's).
    fn reap_idle(&mut self) {
        let Some(mut wheel) = self.wheel.take() else {
            return;
        };
        let now = Instant::now();
        for id in wheel.expired(now) {
            let Some(conn) = self.conns.get(&id) else {
                continue; // closed since it was scheduled
            };
            let idle = now.duration_since(conn.last_active);
            let busy =
                !conn.slots.is_empty() || conn.wpos < conn.wbuf.len();
            if idle >= wheel.timeout && !busy {
                let mut c = self.conns.remove(&id).expect("just looked up");
                c.dead = true;
                self.finish_or_keep(id, c); // closes + releases the lane
            } else {
                // still alive (or mid-request): fire again when its
                // timeout could next elapse
                let remaining = wheel.timeout.saturating_sub(idle);
                wheel.schedule(id, remaining);
            }
        }
        self.wheel = Some(wheel);
    }

    /// Readiness on a connection fd: read what's there, resolve the
    /// codec if still probing, dispatch every complete line (JSON) or
    /// frame (binary), flush what's writable, close if done.
    fn conn_event(&mut self, id: u64, mask: u32) {
        let Some(mut conn) = self.conns.remove(&id) else {
            return;
        };
        if mask & EPOLLERR != 0 {
            conn.dead = true;
        }
        if !conn.dead && !conn.eof && mask & (EPOLLIN | EPOLLRDHUP | EPOLLHUP) != 0 {
            if read_ready(&mut conn, self.read_budget) > 0 {
                // incoming bytes = the peer is alive; stamp for the
                // idle-timeout wheel
                conn.last_active = Instant::now();
            }
        }
        if conn.codec == Codec::Probe && !conn.dead {
            self.resolve_codec(&mut conn);
        }
        if !conn.dead {
            match conn.codec {
                Codec::Json => self.dispatch_lines(&mut conn, id),
                Codec::Binary => self.dispatch_frames(&mut conn, id),
                Codec::Probe => {} // still ambiguous: wait for bytes
            }
        }
        self.pump(&mut conn, id);
        self.finish_or_keep(id, conn);
    }

    /// Decide a probing connection's codec from its buffered head. The
    /// first bytes either diverge from the `LRBF` magic (→ JSON, buffer
    /// untouched — it is the head of the first line) or complete the
    /// 8-byte client hello (→ ack + binary). A magic-matched hello with
    /// the wrong version/reserved bytes is refused with the close frame:
    /// the peer speaks OUR framing but a dialect we don't — answering in
    /// JSON would be garbage to it.
    fn resolve_codec(&mut self, conn: &mut Conn) {
        let hello = binframe::client_hello();
        let n = conn.rbuf.len().min(binframe::HELLO_LEN);
        let magic_n = n.min(binframe::MAGIC.len());
        if conn.rbuf[..magic_n] != hello[..magic_n] {
            conn.codec = Codec::Json;
        } else if n == binframe::HELLO_LEN {
            if conn.rbuf[..binframe::HELLO_LEN] == hello[..] {
                conn.rbuf.drain(..binframe::HELLO_LEN);
                conn.wbuf.extend_from_slice(&binframe::server_hello());
                conn.codec = Codec::Binary;
                self.front.note_binary_conn();
            } else {
                conn.rbuf.clear(); // the refused hello is not a frame
                conn.wbuf
                    .extend_from_slice(&binframe::bad_frame_close_frame());
                conn.eof = true; // flush the refusal, then close
                conn.codec = Codec::Binary;
            }
        } else if conn.eof {
            // half-closed mid-probe with a strict magic prefix buffered:
            // treat it as the partial final JSON line, exactly like the
            // threaded path's byte-at-a-time probe hitting EOF
            conn.codec = Codec::Json;
        }
        // else: a strict prefix of the hello — keep probing
    }

    /// Frame + dispatch every complete JSON line, compacting the read
    /// buffer ONCE per round (a per-line drain would memmove the whole
    /// remainder per request under pipelined bursts).
    fn dispatch_lines(&mut self, conn: &mut Conn, id: u64) {
        let mut consumed = 0usize;
        while !conn.dead {
            let Some((end, next)) = next_line_bounds(&conn.rbuf, consumed)
            else {
                break;
            };
            // parse in place while the buffer is borrowed (`Op` owns
            // its data, so no per-line String copy on the poll
            // thread's hot path); invalid UTF-8 closes the
            // connection with no response — the same observable
            // behavior as the threaded path, whose `read_line` fails
            // with InvalidData there
            let op = match std::str::from_utf8(&conn.rbuf[consumed..end]) {
                Ok(line) => parse_op(line),
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            };
            consumed = next;
            self.dispatch(conn, id, op);
        }
        if consumed > 0 {
            conn.rbuf.drain(..consumed);
        }
        if conn.eof && !conn.dead && !conn.rbuf.is_empty() {
            // the peer half-closed with an unterminated final line:
            // serve it, exactly like the threaded path's
            // BufReader::read_line returning the partial line at EOF
            // (invalid UTF-8 closes unanswered there too)
            let tail = std::mem::take(&mut conn.rbuf);
            match std::str::from_utf8(&tail) {
                Ok(line) => {
                    let op = parse_op(line);
                    self.dispatch(conn, id, op);
                }
                Err(_) => conn.dead = true,
            }
        }
    }

    /// Frame + dispatch every complete binary frame. Framing violations
    /// split by severity exactly like the threaded path: an oversized
    /// length prefix or a torn frame at EOF means the byte stream can no
    /// longer be trusted — answer the typed `bad_frame` error and close;
    /// an in-body shape violation surfaces from `decode_request` as a
    /// typed error on a connection that stays framed and alive.
    fn dispatch_frames(&mut self, conn: &mut Conn, id: u64) {
        let mut consumed = 0usize;
        while !conn.dead {
            match binframe::split_frame(&conn.rbuf, consumed) {
                binframe::Framing::NeedMore => break,
                binframe::Framing::Oversized => {
                    conn.slots.push_back(Slot::Ready(error_response(
                        &coded_error("bad_frame"),
                    )));
                    conn.eof = true;
                    consumed = conn.rbuf.len();
                    break;
                }
                binframe::Framing::Frame { start, end, next } => {
                    let op =
                        binframe::decode_request(&conn.rbuf[start..end]);
                    consumed = next;
                    self.dispatch(conn, id, op);
                }
            }
        }
        if consumed > 0 {
            conn.rbuf.drain(..consumed);
        }
        if conn.eof && !conn.dead && !conn.rbuf.is_empty() {
            // torn frame at EOF: the typed refusal, then close
            conn.rbuf.clear();
            conn.slots.push_back(Slot::Ready(error_response(
                &coded_error("bad_frame"),
            )));
        }
    }

    fn alloc_token(&mut self, conn_id: u64) -> u64 {
        let t = self.next_token;
        self.next_token += 1;
        self.token_conn.insert(t, conn_id);
        t
    }

    fn event_reply(&mut self, conn_id: u64) -> (u64, ReplySender) {
        let token = self.alloc_token(conn_id);
        let reply =
            ReplySender::Event(EventReply::new(token, Arc::clone(&self.completions)));
        (token, reply)
    }

    /// One parsed request → one slot. Mirrors `wire.rs::handle_request`
    /// op for op, with event replies instead of blocking channels. Takes
    /// the already-parsed `Result<(Op, deadline budget)>` so the caller
    /// can parse while the read buffer is still borrowed (no per-line
    /// copy); the third tuple slot is the optional wire `"model"` field,
    /// applied to the connection's sticky binding before dispatch. Lane
    /// ops resolve the binding's CURRENT home under its lock
    /// ([`ShardedFront::with_binding`]), so a submission serializes with
    /// live migration exactly like the threaded path's sync calls.
    fn dispatch(
        &mut self,
        conn: &mut Conn,
        id: u64,
        op: Result<(Op, Option<Duration>, Option<ModelId>)>,
    ) {
        let front = Arc::clone(&self.front);
        let (op, budget, wire_model) = match op {
            Ok(parsed) => parsed,
            Err(e) => {
                conn.slots.push_back(Slot::Ready(error_response(&e)));
                return;
            }
        };
        // cluster ownership: answered synchronously (like the threaded
        // path's early return) so a redirected client never queues work
        if let Some(e) = ownership_guard(&front, conn.state.key, &op) {
            conn.slots.push_back(Slot::Ready(error_response(&e)));
            return;
        }
        // sticky model binding: same contract as the threaded path —
        // first model-bearing op binds the connection, conflicts and
        // unknown ids are refused before any work queues
        if let Err(e) = bind_conn_model(&front, &mut conn.state, wire_model) {
            conn.slots.push_back(Slot::Ready(error_response(&e)));
            return;
        }
        // the budget starts when the request is UNDERSTOOD (same point
        // as the threaded path); saturating via checked_add
        let deadline = budget.and_then(|d| Instant::now().checked_add(d));
        match op {
            Op::Info => conn
                .slots
                .push_back(Slot::Ready(info_response(&front, &conn.state))),
            // liveness probe: answered inline, never queued behind
            // sweeps, so gossip RTTs measure the wire, not the workload
            Op::Ping => conn
                .slots
                .push_back(Slot::Ready(pong_response(&front))),
            Op::Predict(input) => {
                let input = Arc::new(input);
                let (token, reply) = self.event_reply(id);
                conn.slots.push_back(Slot::Waiting {
                    token,
                    kind: PendingKind::Predict {
                        model: conn.state.model,
                        input: Arc::clone(&input),
                        queued_at: Instant::now(),
                    },
                });
                // stateless: dealt to the least-loaded shard; a refused
                // job still resolves through its Dropped completion
                front.submit_predict_dealt_model(
                    conn.state.model,
                    input,
                    reply,
                    deadline,
                );
            }
            Op::Stream(input) => {
                // minted tenants are always single-output reservoirs;
                // the multi-output guard applies to the base model only
                if conn.state.model == BASE_MODEL {
                    if let Err(e) = guard_streamable(front.model()) {
                        conn.slots.push_back(Slot::Ready(error_response(&e)));
                        return;
                    }
                }
                try_acquire_lane(&front, &mut conn.state);
                match conn.state.binding.clone() {
                    Some(b) => {
                        let (token, reply) = self.event_reply(id);
                        conn.slots.push_back(Slot::Waiting {
                            token,
                            kind: PendingKind::Stream,
                        });
                        front.with_binding(&b, |s, l| {
                            s.submit_stream_deadline(l, input, reply, deadline)
                        });
                        b.mark_dirty();
                    }
                    None if conn.state.model != BASE_MODEL => {
                        // the local fallback is built from the BASE
                        // model's planes — serving a tenant from it
                        // would silently answer with the wrong model.
                        // Typed refusal instead (same as the threaded
                        // path).
                        conn.slots.push_back(Slot::Ready(error_response(
                            &coded_error("hub_full"),
                        )));
                    }
                    None => {
                        // hub full: connection-local fallback, inline on
                        // the poll thread (same bits as a hub lane)
                        let outs =
                            stream_fallback(front.model(), &mut conn.state, &input);
                        conn.slots.push_back(Slot::Ready(stream_response(outs)));
                    }
                }
            }
            Op::Train { input, target } => {
                // the row cap is a property of the model being trained:
                // resolve the tenant's own reservoir for the check
                let cap_model = if conn.state.model == BASE_MODEL {
                    if let Err(e) = guard_streamable(front.model()) {
                        conn.slots.push_back(Slot::Ready(error_response(&e)));
                        return;
                    }
                    Arc::clone(front.model())
                } else {
                    match front
                        .registry()
                        .and_then(|r| r.get(conn.state.model))
                    {
                        Some(m) => m,
                        None => {
                            conn.slots.push_back(Slot::Ready(error_response(
                                &coded_error("unknown_model"),
                            )));
                            return;
                        }
                    }
                };
                if let Err(e) = guard_train_rows(&cap_model, input.len()) {
                    conn.slots.push_back(Slot::Ready(error_response(&e)));
                    return;
                }
                // training is lane-resident (the accumulator lives next
                // to the lane state on the home shard's sweeper) — no
                // local-fallback tier
                try_acquire_lane(&front, &mut conn.state);
                match conn.state.binding.clone() {
                    Some(b) => {
                        let (token, reply) = self.event_reply(id);
                        conn.slots.push_back(Slot::Waiting {
                            token,
                            kind: PendingKind::Train,
                        });
                        front.with_binding(&b, |s, l| {
                            s.submit_train_deadline(l, input, target, reply, deadline)
                        });
                        b.mark_dirty();
                    }
                    None => conn.slots.push_back(Slot::Ready(error_response(
                        &hub_full_train_error(),
                    ))),
                }
            }
            Op::Commit { alpha } => match conn.state.binding.clone() {
                Some(b) => {
                    let (token, reply) = self.event_reply(id);
                    conn.slots.push_back(Slot::Waiting {
                        token,
                        kind: PendingKind::Commit,
                    });
                    front.with_binding(&b, |s, l| {
                        s.submit_commit_deadline(l, alpha, reply, deadline)
                    });
                    b.mark_dirty();
                }
                None => conn.slots.push_back(Slot::Ready(error_response(
                    &nothing_to_commit_error(),
                ))),
            },
            Op::Rollback { version } => match conn.state.binding.clone() {
                Some(b) => {
                    let (token, reply) = self.event_reply(id);
                    conn.slots.push_back(Slot::Waiting {
                        token,
                        kind: PendingKind::Rollback,
                    });
                    front.with_binding(&b, |s, l| {
                        s.submit_rollback_deadline(l, version, reply, deadline)
                    });
                    b.mark_dirty();
                }
                None => conn.slots.push_back(Slot::Ready(error_response(
                    &no_lane_error("rollback"),
                ))),
            },
            Op::Checkpoint => match conn.state.binding.clone() {
                Some(b) => {
                    let (token, reply) = self.event_reply(id);
                    conn.slots.push_back(Slot::Waiting {
                        token,
                        kind: PendingKind::Checkpoint,
                    });
                    front.with_binding(&b, |s, l| {
                        s.submit_checkpoint_deadline(l, reply, deadline)
                    });
                }
                None => conn.slots.push_back(Slot::Ready(error_response(
                    &no_lane_error("checkpoint"),
                ))),
            },
            Op::Restore(snap) => {
                if conn.state.model == BASE_MODEL {
                    if let Err(e) = guard_streamable(front.model()) {
                        conn.slots.push_back(Slot::Ready(error_response(&e)));
                        return;
                    }
                }
                // a restore adopts (or acquires) this connection's hub
                // lane — the migration / failover entry point, so it may
                // claim a lane exactly like stream/train do
                try_acquire_lane(&front, &mut conn.state);
                match conn.state.binding.clone() {
                    Some(b) => {
                        let (token, reply) = self.event_reply(id);
                        conn.slots.push_back(Slot::Waiting {
                            token,
                            kind: PendingKind::Restore,
                        });
                        front.with_binding(&b, |s, l| {
                            s.submit_restore_deadline(l, snap, reply, deadline)
                        });
                        b.mark_dirty();
                    }
                    None => conn.slots.push_back(Slot::Ready(error_response(
                        &hub_full_train_error(),
                    ))),
                }
            }
            Op::Reset => {
                conn.state.clear_local();
                match conn.state.binding.clone() {
                    Some(b) => {
                        let (token, reply) = self.event_reply(id);
                        conn.slots.push_back(Slot::Waiting {
                            token,
                            kind: PendingKind::Reset,
                        });
                        front.with_binding(&b, |s, l| {
                            s.submit_reset_deadline(l, reply, deadline)
                        });
                        b.mark_dirty();
                    }
                    None => conn.slots.push_back(Slot::Ready(ok_response())),
                }
            }
            // migration ops run synchronously on the poll thread: a move
            // is a checkpoint + restore round through the shard queues
            // (milliseconds), and serializing it here keeps the
            // slot-FIFO reply order trivially correct
            Op::Migrate { shard } => {
                let json = match handle_migrate(&front, &mut conn.state, shard) {
                    Ok(j) => j,
                    Err(e) => error_response(&e),
                };
                conn.slots.push_back(Slot::Ready(json));
            }
            Op::MigrateIn { lane_id, snap } => {
                let json = match handle_migrate_in(
                    &front,
                    &mut conn.state,
                    lane_id,
                    snap,
                    deadline,
                ) {
                    Ok(j) => j,
                    Err(e) => error_response(&e),
                };
                conn.slots.push_back(Slot::Ready(json));
            }
            // registry ops are process-global and lock-bounded (a mint
            // is one DPG sample, microseconds at serving sizes):
            // answered synchronously like migration
            Op::CreateModel { recipe } => {
                let json = match handle_create_model(&front, &recipe) {
                    Ok(j) => j,
                    Err(e) => error_response(&e),
                };
                conn.slots.push_back(Slot::Ready(json));
            }
            Op::DeleteModel { model } => {
                let json = match handle_delete_model(&front, model) {
                    Ok(j) => j,
                    Err(e) => error_response(&e),
                };
                conn.slots.push_back(Slot::Ready(json));
            }
            Op::ShutdownDrain => {
                // reply first, then drain: the ok flushes through the
                // normal pump path before this connection closes (eof),
                // and the loop head propagates the drain to every peer
                conn.slots.push_back(Slot::Ready(ok_response()));
                conn.eof = true;
                self.draining = true;
            }
        }
    }

    /// Route drained completions to their slots and flush any
    /// connections whose FIFO head became ready.
    fn deliver_completions(&mut self) {
        for (token, completion) in self.completions.drain() {
            let Some(cid) = self.token_conn.remove(&token) else {
                continue;
            };
            let Some(mut conn) = self.conns.remove(&cid) else {
                // connection closed while the job was in flight — the
                // completion (and its exactly-once guarantee) is spent
                continue;
            };
            resolve_slot(&mut conn, token, completion, &self.front);
            self.pump(&mut conn, cid);
            self.finish_or_keep(cid, conn);
        }
    }

    /// Serialize consecutive resolved head slots into the write buffer,
    /// flush as far as the socket accepts, and (de)register EPOLLOUT so
    /// a drained buffer never busy-wakes the loop.
    fn pump(&mut self, conn: &mut Conn, id: u64) {
        while let Some(Slot::Ready(_)) = conn.slots.front() {
            let Some(Slot::Ready(json)) = conn.slots.pop_front() else {
                unreachable!("front() said Ready");
            };
            if conn.codec == Codec::Binary {
                // length-prefixed frame, raw LE floats — no float
                // formatting on the reply path
                binframe::encode_response(&json, &mut conn.wbuf);
            } else {
                conn.wbuf
                    .extend_from_slice(json.to_string_compact().as_bytes());
                conn.wbuf.push(b'\n');
            }
        }
        let flushed_from = conn.wpos;
        flush(conn);
        if conn.wpos > flushed_from {
            // a reply just went out: restart the idle clock, so a client
            // whose request spent longer than the timeout in the queue /
            // sweep isn't reaped the instant its answer flushes — "idle"
            // measures silence in the request-reply cadence, and the
            // server's own processing time is not the client's silence
            conn.last_active = Instant::now();
        }
        if conn.wpos >= conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
        }
        if conn.dead {
            return;
        }
        let mut want = 0u32;
        let pending = conn.wbuf.len() - conn.wpos;
        // backpressure: stop reading while the peer isn't draining its
        // responses (resumes automatically — EPOLLOUT flushes call back
        // into pump, which re-adds EPOLLIN once below the mark). The
        // mark is this thread's budget slice over its live population:
        // `conn` is temporarily out of `self.conns`, hence the +1.
        if !conn.eof
            && pending <= wbuf_high_water(self.wbuf_budget, self.conns.len() + 1)
        {
            want |= EPOLLIN | EPOLLRDHUP;
        }
        if pending > 0 {
            want |= EPOLLOUT;
        }
        if want == 0 {
            // EOF seen, nothing to write, waiting only on sweeper
            // completions: EPOLLHUP/EPOLLERR are unmaskable and
            // level-triggered, so keeping the fd registered with an
            // empty mask would busy-wake the loop on a fully-closed
            // peer. Deregister; the completion path re-registers when
            // there is a response to flush.
            if conn.registered {
                self.ep.del(conn.sock.as_raw_fd());
                conn.registered = false;
            }
        } else if !conn.registered {
            if self.ep.add(conn.sock.as_raw_fd(), want, id).is_ok() {
                conn.registered = true;
                conn.interest = want;
            } else {
                conn.dead = true;
            }
        } else if want != conn.interest {
            if self.ep.modify(conn.sock.as_raw_fd(), want, id).is_ok() {
                conn.interest = want;
            } else {
                conn.dead = true;
            }
        }
    }

    fn finish_or_keep(&mut self, id: u64, mut conn: Conn) {
        if conn.finished() {
            self.ep.del(conn.sock.as_raw_fd());
            if let Some(b) = conn.state.binding.take() {
                if self.draining {
                    // drain keeps the lane alive so the loop can spill
                    // it to --drain-checkpoint after the last close
                    self.drain_keep.push(b);
                } else {
                    // queues a reset ahead of re-issue (or withholds the
                    // lane if the sweeper is gone) — see release_lane
                    self.front.release_binding(&b);
                }
            }
            // dropping `conn` closes the socket; any still-in-flight
            // token resolves later and is discarded in deliver_completions
        } else {
            self.conns.insert(id, conn);
        }
    }
}

/// `true` once the kernel has reported ENOSYS for `accept4` — from then
/// on every accept takes the std `accept` + `set_nonblocking` fallback
/// without retrying the missing syscall.
static ACCEPT4_UNAVAILABLE: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// Accept one pending connection, non-blocking and CLOEXEC from birth:
/// `accept4(SOCK_NONBLOCK | SOCK_CLOEXEC)` — one syscall instead of
/// accept + fcntl — falling back at runtime to `accept` +
/// `set_nonblocking` if the kernel lacks `accept4` (ENOSYS, pre-2.6.28
/// or odd seccomp profiles). Returns the stream plus the peer IP when
/// the kernel handed back a parseable sockaddr (`None` → the caller
/// mints a tagged fallback key).
fn accept_nonblocking(
    listener: &TcpListener,
) -> std::io::Result<(TcpStream, Option<std::net::IpAddr>)> {
    use std::os::unix::io::FromRawFd;
    use std::sync::atomic::Ordering;
    if !ACCEPT4_UNAVAILABLE.load(Ordering::Relaxed) {
        // sockaddr_storage is 128 bytes; family is the first u16
        let mut addr = [0u8; 128];
        let mut len: u32 = addr.len() as u32;
        let fd = unsafe {
            accept4(
                listener.as_raw_fd(),
                addr.as_mut_ptr() as *mut c_void,
                &mut len,
                SOCK_NONBLOCK | SOCK_CLOEXEC,
            )
        };
        if fd >= 0 {
            // SAFETY: accept4 returned a fresh, owned socket fd
            let sock = unsafe { TcpStream::from_raw_fd(fd) };
            return Ok((sock, parse_peer_sockaddr(&addr, len as usize)));
        }
        let err = std::io::Error::last_os_error();
        if err.raw_os_error() != Some(ENOSYS) {
            return Err(err);
        }
        ACCEPT4_UNAVAILABLE.store(true, Ordering::Relaxed);
    }
    let (sock, peer) = listener.accept()?;
    sock.set_nonblocking(true)?;
    Ok((sock, Some(peer.ip())))
}

/// Decode the peer IP out of a raw sockaddr buffer: `sa_family` is the
/// leading native-endian u16; AF_INET puts the 4 address bytes at offset
/// 4 (`sin_addr`, after the u16 port), AF_INET6 the 16 address bytes at
/// offset 8 (`sin6_addr`, after port + flowinfo). Anything else — or a
/// truncated length — is unreadable and maps to `None`.
fn parse_peer_sockaddr(buf: &[u8], len: usize) -> Option<std::net::IpAddr> {
    use std::net::{IpAddr, Ipv4Addr, Ipv6Addr};
    if len < 2 || buf.len() < 2 {
        return None;
    }
    match u16::from_ne_bytes([buf[0], buf[1]]) {
        AF_INET if len >= 8 => Some(IpAddr::V4(Ipv4Addr::new(
            buf[4], buf[5], buf[6], buf[7],
        ))),
        AF_INET6 if len >= 24 => {
            let mut o = [0u8; 16];
            o.copy_from_slice(&buf[8..24]);
            Some(IpAddr::V6(Ipv6Addr::from(o)))
        }
        _ => None,
    }
}

/// Non-blocking read into the connection buffer until the socket is
/// dry, EOF, a hard error, or the per-round fairness budget is spent
/// (the remainder stays readable — level-triggered — and is picked up
/// next round, after other connections get their turn). Returns the
/// bytes taken this round (the idle-timeout activity signal).
fn read_ready(conn: &mut Conn, budget: usize) -> usize {
    // one binary frame may legitimately reach MAX_FRAME_BYTES plus its
    // 4-byte prefix; JSON lines keep the historical line bound
    let cap = match conn.codec {
        Codec::Binary => binframe::MAX_FRAME_BYTES + 4,
        _ => MAX_LINE_BYTES,
    };
    let mut buf = [0u8; 4096];
    let mut taken = 0usize;
    while taken < budget {
        match conn.sock.read(&mut buf) {
            Ok(0) => {
                conn.eof = true;
                break;
            }
            Ok(n) => {
                taken += n;
                conn.rbuf.extend_from_slice(&buf[..n]);
                if conn.rbuf.len() > cap {
                    conn.dead = true;
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    taken
}

/// Bounds of the next complete line at/after `from`: `(end, next)` where
/// `rbuf[from..end]` is the line (newline excluded) and `next` is where
/// the following line starts. Pure scan — the caller compacts the buffer
/// once per readiness round, not per line.
fn next_line_bounds(rbuf: &[u8], from: usize) -> Option<(usize, usize)> {
    let rel = rbuf[from..].iter().position(|&b| b == b'\n')?;
    Some((from + rel, from + rel + 1))
}

/// Turn a completion into its response JSON in the owning connection's
/// slot. Fallbacks here mirror what the threaded path's blocking calls
/// do when the sweeper is gone.
fn resolve_slot(
    conn: &mut Conn,
    token: u64,
    completion: Completion,
    front: &ShardedFront,
) {
    let Some(idx) = conn
        .slots
        .iter()
        .position(|s| matches!(s, Slot::Waiting { token: t, .. } if *t == token))
    else {
        return;
    };
    // take the kind OUT of the slot so the arms below own it and the
    // connection state stays borrowable (restore clears local fallback)
    let Slot::Waiting { kind, .. } =
        std::mem::replace(&mut conn.slots[idx], Slot::Ready(Json::Null))
    else {
        unreachable!("position() matched a Waiting slot");
    };
    // a restore that comes back with values installed its snapshot: the
    // connection-local fallback state (if any) is superseded, exactly
    // like the threaded path's clear_local after a successful restore
    if matches!(
        (&kind, &completion),
        (PendingKind::Restore, Completion::Done(Reply::Vals(_)))
    ) {
        conn.state.clear_local();
    }
    let json = match (kind, completion) {
        (
            PendingKind::Predict { input, queued_at, .. },
            Completion::Done(Reply::Vals(out)),
        ) => predict_response(out, input.len(), queued_at.elapsed().as_secs_f64()),
        // typed sweeper refusal (lane_poisoned, trainer_budget,
        // commit_empty, overloaded, deadline_exceeded, …): same coded
        // response as the threaded wrapper. This arm MUST precede the
        // predict fallback below — an admission shed or an expired
        // deadline is a refusal the client asked for, and silently
        // answering it with an inline predict would defeat the overload
        // protection it exists to provide.
        (_, Completion::Done(Reply::Err(code))) => {
            error_response(&coded_error(code))
        }
        (PendingKind::Predict { model, input, queued_at }, _) => {
            // sweeper gone (job dropped): direct same-precision
            // computation, just like BatchFront::predict's fallback —
            // still identical bits on the wire. The stamped model picks
            // the planes; a tenant deleted mid-flight gets the typed
            // refusal, never the base model's answer
            let resolved = if model == BASE_MODEL {
                Some(Arc::clone(front.model()))
            } else {
                front.registry().and_then(|r| r.get(model))
            };
            match resolved {
                Some(m) => {
                    let steps = input.len();
                    let out = m.predict(input);
                    predict_response(out, steps, queued_at.elapsed().as_secs_f64())
                }
                None => error_response(&coded_error("unknown_model")),
            }
        }
        (PendingKind::Stream, Completion::Done(Reply::Vals(outs))) => {
            stream_response(outs)
        }
        (PendingKind::Train, Completion::Done(Reply::Vals(v))) => {
            train_response(v.first().copied().unwrap_or(0.0) as u64)
        }
        // commit / rollback / restore all answer with the lane's active
        // readout version
        (
            PendingKind::Commit | PendingKind::Rollback | PendingKind::Restore,
            Completion::Done(Reply::Vals(v)),
        ) => version_response(v.first().copied().unwrap_or(0.0) as u64),
        (PendingKind::Checkpoint, Completion::Done(Reply::Snap(snap))) => {
            checkpoint_response(&snap)
        }
        (PendingKind::Reset, Completion::Done(_)) => ok_response(),
        // sweeper dead before the job ran, or a reply shape impossible
        // for the op: the deterministic "unavailable" error, same as the
        // threaded wrappers' dropped-channel mapping
        _ => error_response(&unavailable_error()),
    };
    conn.slots[idx] = Slot::Ready(json);
}

/// Write as much of the pending buffer as the socket accepts. Under an
/// armed short-write fault ([`super::fault::set_short_writes`]) the call
/// is shaped to at most ONE chunk-bounded `write(2)` after the
/// configured delay, then behaves as if the socket reported WouldBlock —
/// a deterministic slow reader for the chaos suite (level-triggered
/// EPOLLOUT re-delivers, so the buffer still drains chunk by chunk).
fn flush(conn: &mut Conn) {
    if let Some((chunk, delay)) = super::fault::short_write_chunk() {
        if conn.wpos >= conn.wbuf.len() {
            return;
        }
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        let end = (conn.wpos + chunk.max(1)).min(conn.wbuf.len());
        match conn.sock.write(&conn.wbuf[conn.wpos..end]) {
            Ok(0) => conn.dead = true,
            Ok(n) => conn.wpos += n,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::Interrupted => {}
            Err(_) => conn.dead = true,
        }
        return;
    }
    while conn.wpos < conn.wbuf.len() {
        match conn.sock.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_framing_handles_partial_multiple_and_empty_lines() {
        let buf = b"abc\ndef".to_vec();
        assert_eq!(next_line_bounds(&buf, 0), Some((3, 4)));
        assert_eq!(&buf[0..3], b"abc");
        // partial tail: no complete line yet
        assert_eq!(next_line_bounds(&buf, 4), None);
        let buf = b"abc\ndef\n\nx".to_vec();
        let (end1, next1) = next_line_bounds(&buf, 0).unwrap();
        assert_eq!(&buf[0..end1], b"abc");
        let (end2, next2) = next_line_bounds(&buf, next1).unwrap();
        assert_eq!(&buf[next1..end2], b"def");
        // empty line between newlines
        let (end3, next3) = next_line_bounds(&buf, next2).unwrap();
        assert_eq!(end3, next2, "empty line has zero length");
        assert_eq!(next_line_bounds(&buf, next3), None, "partial 'x' tail");
    }

    #[test]
    fn sockaddr_parsing_decodes_v4_v6_and_rejects_junk() {
        // AF_INET, port 0x1234, 127.0.0.1
        let mut v4 = [0u8; 128];
        v4[..2].copy_from_slice(&AF_INET.to_ne_bytes());
        v4[2] = 0x12;
        v4[3] = 0x34;
        v4[4..8].copy_from_slice(&[127, 0, 0, 1]);
        assert_eq!(
            parse_peer_sockaddr(&v4, 16),
            Some("127.0.0.1".parse().unwrap())
        );
        // AF_INET6, ::1
        let mut v6 = [0u8; 128];
        v6[..2].copy_from_slice(&AF_INET6.to_ne_bytes());
        v6[23] = 1; // last byte of the address = 1
        assert_eq!(
            parse_peer_sockaddr(&v6, 28),
            Some("::1".parse().unwrap())
        );
        // unknown family / truncated → unreadable
        let mut unix = [0u8; 128];
        unix[0] = 1; // AF_UNIX
        assert_eq!(parse_peer_sockaddr(&unix, 16), None);
        assert_eq!(parse_peer_sockaddr(&v4, 1), None);
        assert_eq!(parse_peer_sockaddr(&v6, 10), None);
    }

    #[test]
    fn accept4_path_serves_a_real_connection() {
        // exercise accept_nonblocking directly against a loopback
        // listener: the accepted socket must be non-blocking (a read
        // with no data errs WouldBlock instead of parking) and the peer
        // IP must decode
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        // the connection may need a beat to land in the backlog
        let (mut sock, peer) = loop {
            match accept_nonblocking(&listener) {
                Ok(got) => break got,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => panic!("accept_nonblocking: {e}"),
            }
        };
        assert_eq!(peer, Some("127.0.0.1".parse().unwrap()));
        let mut buf = [0u8; 8];
        match sock.read(&mut buf) {
            Err(e) => assert_eq!(
                e.kind(),
                ErrorKind::WouldBlock,
                "accepted socket must be non-blocking"
            ),
            Ok(n) => panic!("expected WouldBlock, read {n} bytes"),
        }
        drop(client);
    }

    #[test]
    fn idle_wheel_fires_after_timeout_and_not_before() {
        let t0 = Instant::now();
        let timeout = Duration::from_millis(400);
        let mut wheel = IdleWheel::new(timeout, t0);
        wheel.schedule(7, timeout);
        // well before the timeout: the slot must not have fired
        let early: Vec<u64> = wheel.expired(t0 + Duration::from_millis(120));
        assert!(early.is_empty(), "fired {early:?} before the timeout");
        // past the timeout (+ a tick of slack): it must fire
        let late = wheel.expired(t0 + timeout + wheel.tick + wheel.tick);
        assert_eq!(late, vec![7]);
        // re-scheduling with remaining time lands in a later slot
        wheel.schedule(7, Duration::from_millis(100));
        let again = wheel.expired(t0 + timeout + Duration::from_millis(900));
        assert_eq!(again, vec![7]);
    }

    #[test]
    fn wbuf_high_water_apportions_the_shared_budget() {
        let b = WBUF_TOTAL_BUDGET;
        // up to 64 live connections each keep the full 1 MiB ceiling
        assert_eq!(wbuf_high_water(b, 1), 1 << 20);
        assert_eq!(wbuf_high_water(b, 64), 1 << 20);
        // past that the 64 MiB process budget divides down
        assert_eq!(wbuf_high_water(b, 128), 512 << 10);
        assert_eq!(wbuf_high_water(b, 1024), 64 << 10);
        // the floor keeps a huge fleet from starving each connection
        assert_eq!(wbuf_high_water(b, 100_000), 64 << 10);
        // degenerate zero-live input must not divide by zero
        assert_eq!(wbuf_high_water(b, 0), 1 << 20);
        // a poll thread's slice divides ITS budget, not the process's:
        // at P=4 the per-thread 16 MiB slice halves the 128-conn mark
        assert_eq!(wbuf_high_water((b / 4).max(WBUF_BUDGET_FLOOR), 128), 128 << 10);
        // the per-thread floor still guarantees a sane mark at huge P
        assert_eq!(wbuf_high_water(WBUF_BUDGET_FLOOR, 8), 128 << 10);
    }

    #[test]
    fn eventfd_signal_wakes_epoll_with_its_token() {
        let ep = Epoll::new().unwrap();
        let efd = EventFd::new().unwrap();
        ep.add(efd.fd, EPOLLIN, 9).unwrap();
        efd.signal();
        efd.signal(); // coalesces: still one readable event
        let mut events = vec![EpollEvent { events: 0, data: 0 }; 4];
        let n = ep.wait(&mut events, -1).unwrap();
        assert_eq!(n, 1);
        let token = events[0].data;
        assert_eq!(token, 9);
        efd.drain_counter();
    }
}
