//! Event-driven serving transport: a hand-rolled epoll readiness loop.
//!
//! The thread-per-connection transport burns one OS thread per client
//! just to park in `read_line` — at 10k idle streaming connections that
//! is 10k stacks and 10k scheduler entries doing nothing. The paper's
//! O(N) step makes the arithmetic cheap enough that those threads ARE
//! the serving cost. This module replaces them with ONE poll thread:
//!
//! ```text
//!             ┌─────────────────────────────────────────────────────┐
//!             │                  poll thread (epoll)                │
//!  listener ──┤ accept → register fd (non-blocking, level-trig.)    │
//!  conn fd ───┤ readable → rbuf → line frame → dispatch:            │
//!             │    info / errors / hub-less stream → Ready slot     │
//!             │    predict/stream/reset → Waiting slot + EventReply │
//!             │                    │ submit ───────────▶ shard queues
//!             │                    ▼                        │ sweep
//!  eventfd ◀──┼──────── CompletionQueue.push ◀── ReplySender┘
//!             │ wake → drain completions → resolve slot → wbuf      │
//!             │ writable → flush wbuf (EPOLLOUT only while pending) │
//!             └─────────────────────────────────────────────────────┘
//! ```
//!
//! Raw `libc` syscalls via `extern "C"` — `epoll_create1` / `epoll_ctl`
//! / `epoll_wait`, plus an `eventfd` the sweepers signal when they
//! complete a job (std links libc on Linux; no new crates). Sockets stay
//! `std::net` types flipped to non-blocking.
//!
//! Invariants:
//!
//! * **FIFO responses.** Each connection keeps an ordered slot queue;
//!   a response is flushed only when every earlier request's slot is
//!   resolved, so pipelined clients see replies in request order even
//!   though shard queues complete out of order.
//! * **Exactly-one completion.** Every queued job carries an
//!   [`EventReply`] whose `Drop` delivers a `Dropped` completion if the
//!   sweeper dies or refuses the job — a pending slot can never leak, so
//!   the loop registers slots unconditionally and handles fallbacks
//!   (direct predict / error response) at completion time.
//! * **Same decision tree as the threaded path.** `dispatch` mirrors
//!   `wire.rs::handle_request` op for op on the shared transport-
//!   agnostic core, so responses are bit-identical between transports
//!   (tested in `wire.rs` and `rust/tests/pipeline.rs`).
//! * **Thread-free idle.** An idle connection costs one fd and one
//!   `Conn` entry. The box runs S sweepers + 1 poll thread regardless
//!   of connection count (asserted in `rust/tests/pipeline.rs`).
//!
//! Hub-overflow streaming (beyond `S × 64` lanes) runs its
//! connection-local fallback inline on the poll thread — O(T·N) per
//! request of the same bit-identical arithmetic; acceptable because
//! overflow lanes are the degraded tier by definition.

#![cfg(target_os = "linux")]

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::raw::{c_int, c_void};
use std::os::unix::io::AsRawFd;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

use super::front::{Completion, CompletionQueue, EventReply, ReplySender};
use super::shard::ShardedFront;
use super::wire::{
    error_response, guard_streamable, info_response, ip_key, ok_response, parse_op,
    predict_response, stream_fallback, stream_response, try_acquire_lane, ConnState,
    Op,
};

// ---------------------------------------------------------------------------
// raw syscall surface (glibc symbols; std already links libc on Linux)
// ---------------------------------------------------------------------------

/// Kernel epoll event record. On x86-64 the kernel ABI packs this struct
/// (no padding between `events` and `data`); elsewhere it is naturally
/// aligned. Fields are only ever read BY VALUE (taking a reference to a
/// packed field would be UB).
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout_ms: c_int,
    ) -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    #[link_name = "read"]
    fn c_read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    #[link_name = "write"]
    fn c_write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    #[link_name = "close"]
    fn c_close(fd: c_int) -> c_int;
}

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;
const EINTR: i32 = 4;
const ENOMEM: i32 = 12;
const ENFILE: i32 = 23;
const EMFILE: i32 = 24;
const EPROTO: i32 = 71;
const ECONNABORTED: i32 = 103;
const ENOBUFS: i32 = 105;

/// Thin RAII epoll instance.
struct Epoll {
    fd: c_int,
}

impl Epoll {
    fn new() -> Result<Self> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        anyhow::ensure!(
            fd >= 0,
            "epoll_create1: {}",
            std::io::Error::last_os_error()
        );
        Ok(Self { fd })
    }

    fn ctl(&self, op: c_int, fd: c_int, events: u32, token: u64) -> Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
        anyhow::ensure!(rc == 0, "epoll_ctl: {}", std::io::Error::last_os_error());
        Ok(())
    }

    fn add(&self, fd: c_int, events: u32, token: u64) -> Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    fn modify(&self, fd: c_int, events: u32, token: u64) -> Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    fn del(&self, fd: c_int) {
        // failure only means the fd is already gone — nothing to unwind
        let _ = self.ctl(EPOLL_CTL_DEL, fd, 0, 0);
    }

    /// Block until at least one event is ready (retrying on EINTR).
    fn wait(&self, events: &mut [EpollEvent]) -> Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, -1)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.raw_os_error() != Some(EINTR) {
                return Err(anyhow!("epoll_wait: {err}"));
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            c_close(self.fd);
        }
    }
}

/// The sweeper→poll wake channel: sweeper threads `signal()` after
/// pushing a completion, the poll thread `drain_counter()`s on
/// readability. The counter semantics coalesce any number of signals
/// into one readable event — exactly what a batch drain wants.
struct EventFd {
    fd: c_int,
}

impl EventFd {
    fn new() -> Result<Self> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        anyhow::ensure!(fd >= 0, "eventfd: {}", std::io::Error::last_os_error());
        Ok(Self { fd })
    }

    fn signal(&self) {
        let one: u64 = 1;
        // EAGAIN (counter saturated) still leaves the fd readable, so a
        // lost increment cannot lose the wake
        let _ = unsafe { c_write(self.fd, &one as *const u64 as *const c_void, 8) };
    }

    fn drain_counter(&self) {
        let mut v: u64 = 0;
        let _ = unsafe { c_read(self.fd, &mut v as *mut u64 as *mut c_void, 8) };
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe {
            c_close(self.fd);
        }
    }
}

// ---------------------------------------------------------------------------
// connection table
// ---------------------------------------------------------------------------

const LISTENER_TOKEN: u64 = u64::MAX;
const WAKE_TOKEN: u64 = u64::MAX - 1;
/// A single request line longer than this is not protocol traffic; the
/// connection is dropped instead of buffering it unboundedly. Complete
/// lines are framed out of the buffer every readiness round, so the
/// buffer only approaches this bound when one LINE does.
const MAX_LINE_BYTES: usize = 64 << 20;
/// Max bytes read from one connection per readiness round: level-
/// triggered epoll re-delivers whatever is left, so a firehose client
/// yields the poll thread to its peers every `READ_BUDGET` bytes
/// instead of monopolizing the loop until its socket runs dry.
const READ_BUDGET: usize = 256 << 10;
/// Write-side backpressure: while more than this many unflushed response
/// bytes are pending on a connection, the loop stops reading from it
/// (EPOLLIN dropped), so a client that pipelines requests without ever
/// draining replies throttles ITSELF instead of growing server memory —
/// the event-loop analogue of the threaded path blocking in `write_all`.
const WBUF_HIGH_WATER: usize = 1 << 20;
/// Events drained per `epoll_wait` round.
const EVENT_BATCH: usize = 128;

/// What an in-flight (queued-to-a-sweeper) request resolves into.
enum PendingKind {
    /// The input is kept (shared with the queued job via `Arc` — no
    /// copy) so a `Dropped` completion (sweeper gone) can fall back to
    /// the direct same-precision `Model::predict`, exactly like
    /// `BatchFront::predict` does on the threaded path.
    Predict {
        input: Arc<Vec<f64>>,
        queued_at: Instant,
    },
    Stream,
    Reset,
}

/// One response slot in a connection's FIFO: resolved (`Ready`) slots at
/// the head flush to the socket; a `Waiting` head holds every later
/// response back so pipelined replies stay in request order.
enum Slot {
    Ready(Json),
    Waiting { token: u64, kind: PendingKind },
}

struct Conn {
    sock: TcpStream,
    state: ConnState,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    /// Bytes of `wbuf` already written to the socket.
    wpos: usize,
    slots: VecDeque<Slot>,
    /// Last epoll interest mask registered for this fd.
    interest: u32,
    /// Whether the fd is currently registered with epoll. Deregistered
    /// while the wanted mask is empty (EOF seen, nothing to write,
    /// waiting only on sweeper completions): EPOLLHUP/EPOLLERR are
    /// unmaskable and level-triggered, so a fully-closed peer would
    /// busy-wake the loop through an empty interest mask otherwise.
    registered: bool,
    /// Peer sent EOF: serve out pending slots, flush, then close.
    eof: bool,
    /// Hard error: close as soon as observed.
    dead: bool,
}

impl Conn {
    fn finished(&self) -> bool {
        self.dead || (self.eof && self.slots.is_empty() && self.wpos >= self.wbuf.len())
    }
}

// ---------------------------------------------------------------------------
// the loop
// ---------------------------------------------------------------------------

struct EventLoop {
    ep: Epoll,
    wake: Arc<EventFd>,
    completions: Arc<CompletionQueue>,
    front: Arc<ShardedFront>,
    conns: HashMap<u64, Conn>,
    /// In-flight reply token → owning connection id.
    token_conn: HashMap<u64, u64>,
    next_conn_id: u64,
    next_token: u64,
    accepted: usize,
    accepting: bool,
    max_conns: Option<usize>,
}

/// Serve every connection of `listener` from this thread with an epoll
/// readiness loop. Returns once `max_conns` connections have been
/// accepted AND have all closed (`None`: runs forever). Called by
/// [`super::wire::serve_on`], which owns the sweeper lifecycle.
pub(crate) fn serve_event_loop(
    listener: TcpListener,
    front: Arc<ShardedFront>,
    max_conns: Option<usize>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let ep = Epoll::new()?;
    let wake = Arc::new(EventFd::new()?);
    let completions = {
        let w = Arc::clone(&wake);
        CompletionQueue::new(Box::new(move || w.signal()))
    };
    ep.add(listener.as_raw_fd(), EPOLLIN, LISTENER_TOKEN)?;
    ep.add(wake.fd, EPOLLIN, WAKE_TOKEN)?;
    let mut lp = EventLoop {
        ep,
        wake,
        completions,
        front,
        conns: HashMap::new(),
        token_conn: HashMap::new(),
        next_conn_id: 0,
        next_token: 0,
        accepted: 0,
        accepting: true,
        max_conns,
    };
    let mut events = vec![EpollEvent { events: 0, data: 0 }; EVENT_BATCH];
    let mut accept_err: Option<anyhow::Error> = None;
    loop {
        if let Some(max) = lp.max_conns {
            if lp.accepting && lp.accepted >= max {
                lp.stop_accepting(&listener);
            }
        }
        if !lp.accepting && lp.conns.is_empty() {
            break;
        }
        let n = lp.ep.wait(&mut events)?;
        for ev in &events[..n] {
            // copy packed fields by value (references into a packed
            // struct would be UB)
            let (token, mask) = (ev.data, ev.events);
            match token {
                LISTENER_TOKEN => {
                    if let Err(e) = lp.accept_ready(&listener) {
                        // like the threaded path: stop accepting, serve
                        // the live connections out, then surface the
                        // accept error
                        lp.stop_accepting(&listener);
                        accept_err = Some(e);
                    }
                }
                WAKE_TOKEN => {
                    lp.wake.drain_counter();
                    lp.deliver_completions();
                }
                id => lp.conn_event(id, mask),
            }
        }
    }
    match accept_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

impl EventLoop {
    fn stop_accepting(&mut self, listener: &TcpListener) {
        if self.accepting {
            self.accepting = false;
            self.ep.del(listener.as_raw_fd());
        }
    }

    /// Drain the accept backlog (level-triggered: whatever is left stays
    /// readable for the next round).
    fn accept_ready(&mut self, listener: &TcpListener) -> Result<()> {
        loop {
            if let Some(max) = self.max_conns {
                if self.accepted >= max {
                    return Ok(()); // the loop head deregisters next round
                }
            }
            match listener.accept() {
                Ok((sock, peer)) => {
                    // same key derivation as the threaded path: peer IP,
                    // so reconnects keep their home shard (accept(2)
                    // hands the address over directly — the tagged
                    // fallback key only exists for transports that must
                    // query it after the fact)
                    let key = ip_key(&peer.ip());
                    self.accepted += 1;
                    // a connection that can't be registered is dropped
                    // (closed), never fatal to the serving loop
                    let _ = self.register_conn(sock, key);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => match e.raw_os_error() {
                    // the pending connection was RST before accept —
                    // it is consumed; keep draining the backlog
                    Some(ECONNABORTED) | Some(EPROTO) => continue,
                    // resource exhaustion (fd table full, no buffers):
                    // not this listener's death sentence — yield the
                    // round with a brief throttle (the level-triggered
                    // listener would otherwise busy-spin while the
                    // condition persists) and retry on the next wake
                    Some(EMFILE) | Some(ENFILE) | Some(ENOBUFS) | Some(ENOMEM) => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        return Ok(());
                    }
                    _ => return Err(e.into()),
                },
            }
        }
    }

    fn register_conn(&mut self, sock: TcpStream, key: u64) -> Result<()> {
        sock.set_nonblocking(true)?;
        let id = self.next_conn_id;
        self.next_conn_id += 1;
        let interest = EPOLLIN | EPOLLRDHUP;
        self.ep.add(sock.as_raw_fd(), interest, id)?;
        self.conns.insert(
            id,
            Conn {
                sock,
                state: ConnState::new(self.front.shard_for_key(key)),
                rbuf: Vec::new(),
                wbuf: Vec::new(),
                wpos: 0,
                slots: VecDeque::new(),
                interest,
                registered: true,
                eof: false,
                dead: false,
            },
        );
        Ok(())
    }

    /// Readiness on a connection fd: read what's there, dispatch every
    /// complete line, flush what's writable, close if done.
    fn conn_event(&mut self, id: u64, mask: u32) {
        let Some(mut conn) = self.conns.remove(&id) else {
            return;
        };
        if mask & EPOLLERR != 0 {
            conn.dead = true;
        }
        if !conn.dead && !conn.eof && mask & (EPOLLIN | EPOLLRDHUP | EPOLLHUP) != 0 {
            read_ready(&mut conn);
            // frame + dispatch every complete line, compacting the read
            // buffer ONCE per round (a per-line drain would memmove the
            // whole remainder per request under pipelined bursts)
            let mut consumed = 0usize;
            while !conn.dead {
                let Some((end, next)) = next_line_bounds(&conn.rbuf, consumed)
                else {
                    break;
                };
                // parse in place while the buffer is borrowed (`Op` owns
                // its data, so no per-line String copy on the poll
                // thread's hot path); invalid UTF-8 closes the
                // connection with no response — the same observable
                // behavior as the threaded path, whose `read_line` fails
                // with InvalidData there
                let op = match std::str::from_utf8(&conn.rbuf[consumed..end]) {
                    Ok(line) => parse_op(line),
                    Err(_) => {
                        conn.dead = true;
                        break;
                    }
                };
                consumed = next;
                self.dispatch(&mut conn, id, op);
            }
            if consumed > 0 {
                conn.rbuf.drain(..consumed);
            }
            if conn.eof && !conn.dead && !conn.rbuf.is_empty() {
                // the peer half-closed with an unterminated final line:
                // serve it, exactly like the threaded path's
                // BufReader::read_line returning the partial line at EOF
                // (invalid UTF-8 closes unanswered there too)
                let tail = std::mem::take(&mut conn.rbuf);
                match std::str::from_utf8(&tail) {
                    Ok(line) => {
                        let op = parse_op(line);
                        self.dispatch(&mut conn, id, op);
                    }
                    Err(_) => conn.dead = true,
                }
            }
        }
        self.pump(&mut conn, id);
        self.finish_or_keep(id, conn);
    }

    fn alloc_token(&mut self, conn_id: u64) -> u64 {
        let t = self.next_token;
        self.next_token += 1;
        self.token_conn.insert(t, conn_id);
        t
    }

    fn event_reply(&mut self, conn_id: u64) -> (u64, ReplySender) {
        let token = self.alloc_token(conn_id);
        let reply =
            ReplySender::Event(EventReply::new(token, Arc::clone(&self.completions)));
        (token, reply)
    }

    /// One parsed request → one slot. Mirrors `wire.rs::handle_request`
    /// op for op, with event replies instead of blocking channels. Takes
    /// the already-parsed `Result<Op>` so the caller can parse while the
    /// read buffer is still borrowed (no per-line copy).
    fn dispatch(&mut self, conn: &mut Conn, id: u64, op: Result<Op>) {
        let front = Arc::clone(&self.front);
        match op {
            Err(e) => conn.slots.push_back(Slot::Ready(error_response(&e))),
            Ok(Op::Info) => conn
                .slots
                .push_back(Slot::Ready(info_response(&front, &conn.state))),
            Ok(Op::Predict(input)) => {
                let input = Arc::new(input);
                let (token, reply) = self.event_reply(id);
                conn.slots.push_back(Slot::Waiting {
                    token,
                    kind: PendingKind::Predict {
                        input: Arc::clone(&input),
                        queued_at: Instant::now(),
                    },
                });
                // stateless: dealt to the least-loaded shard; a refused
                // job still resolves through its Dropped completion
                front.submit_predict_dealt(input, reply);
            }
            Ok(Op::Stream(input)) => {
                if let Err(e) = guard_streamable(front.model()) {
                    conn.slots.push_back(Slot::Ready(error_response(&e)));
                    return;
                }
                try_acquire_lane(&front, &mut conn.state);
                match conn.state.lane {
                    Some(lane) => {
                        let (token, reply) = self.event_reply(id);
                        conn.slots.push_back(Slot::Waiting {
                            token,
                            kind: PendingKind::Stream,
                        });
                        front
                            .shard(conn.state.shard_idx)
                            .submit_stream(lane, input, reply);
                    }
                    None => {
                        // hub full: connection-local fallback, inline on
                        // the poll thread (same bits as a hub lane)
                        let outs =
                            stream_fallback(front.model(), &mut conn.state, &input);
                        conn.slots.push_back(Slot::Ready(stream_response(outs)));
                    }
                }
            }
            Ok(Op::Reset) => {
                conn.state.clear_local();
                match conn.state.lane {
                    Some(lane) => {
                        let (token, reply) = self.event_reply(id);
                        conn.slots.push_back(Slot::Waiting {
                            token,
                            kind: PendingKind::Reset,
                        });
                        front.shard(conn.state.shard_idx).submit_reset(lane, reply);
                    }
                    None => conn.slots.push_back(Slot::Ready(ok_response())),
                }
            }
        }
    }

    /// Route drained completions to their slots and flush any
    /// connections whose FIFO head became ready.
    fn deliver_completions(&mut self) {
        for (token, completion) in self.completions.drain() {
            let Some(cid) = self.token_conn.remove(&token) else {
                continue;
            };
            let Some(mut conn) = self.conns.remove(&cid) else {
                // connection closed while the job was in flight — the
                // completion (and its exactly-once guarantee) is spent
                continue;
            };
            resolve_slot(&mut conn, token, completion, &self.front);
            self.pump(&mut conn, cid);
            self.finish_or_keep(cid, conn);
        }
    }

    /// Serialize consecutive resolved head slots into the write buffer,
    /// flush as far as the socket accepts, and (de)register EPOLLOUT so
    /// a drained buffer never busy-wakes the loop.
    fn pump(&mut self, conn: &mut Conn, id: u64) {
        while let Some(Slot::Ready(_)) = conn.slots.front() {
            let Some(Slot::Ready(json)) = conn.slots.pop_front() else {
                unreachable!("front() said Ready");
            };
            conn.wbuf
                .extend_from_slice(json.to_string_compact().as_bytes());
            conn.wbuf.push(b'\n');
        }
        flush(conn);
        if conn.wpos >= conn.wbuf.len() {
            conn.wbuf.clear();
            conn.wpos = 0;
        }
        if conn.dead {
            return;
        }
        let mut want = 0u32;
        let pending = conn.wbuf.len() - conn.wpos;
        // backpressure: stop reading while the peer isn't draining its
        // responses (resumes automatically — EPOLLOUT flushes call back
        // into pump, which re-adds EPOLLIN once below the mark)
        if !conn.eof && pending <= WBUF_HIGH_WATER {
            want |= EPOLLIN | EPOLLRDHUP;
        }
        if pending > 0 {
            want |= EPOLLOUT;
        }
        if want == 0 {
            // EOF seen, nothing to write, waiting only on sweeper
            // completions: EPOLLHUP/EPOLLERR are unmaskable and
            // level-triggered, so keeping the fd registered with an
            // empty mask would busy-wake the loop on a fully-closed
            // peer. Deregister; the completion path re-registers when
            // there is a response to flush.
            if conn.registered {
                self.ep.del(conn.sock.as_raw_fd());
                conn.registered = false;
            }
        } else if !conn.registered {
            if self.ep.add(conn.sock.as_raw_fd(), want, id).is_ok() {
                conn.registered = true;
                conn.interest = want;
            } else {
                conn.dead = true;
            }
        } else if want != conn.interest {
            if self.ep.modify(conn.sock.as_raw_fd(), want, id).is_ok() {
                conn.interest = want;
            } else {
                conn.dead = true;
            }
        }
    }

    fn finish_or_keep(&mut self, id: u64, conn: Conn) {
        if conn.finished() {
            self.ep.del(conn.sock.as_raw_fd());
            if let Some(lane) = conn.state.lane {
                // queues a reset ahead of re-issue (or withholds the
                // lane if the sweeper is gone) — see release_lane
                self.front.shard(conn.state.shard_idx).release_lane(lane);
            }
            // dropping `conn` closes the socket; any still-in-flight
            // token resolves later and is discarded in deliver_completions
        } else {
            self.conns.insert(id, conn);
        }
    }
}

/// Non-blocking read into the connection buffer until the socket is
/// dry, EOF, a hard error, or the per-round fairness budget is spent
/// (the remainder stays readable — level-triggered — and is picked up
/// next round, after other connections get their turn).
fn read_ready(conn: &mut Conn) {
    let mut buf = [0u8; 4096];
    let mut taken = 0usize;
    while taken < READ_BUDGET {
        match conn.sock.read(&mut buf) {
            Ok(0) => {
                conn.eof = true;
                break;
            }
            Ok(n) => {
                taken += n;
                conn.rbuf.extend_from_slice(&buf[..n]);
                if conn.rbuf.len() > MAX_LINE_BYTES {
                    conn.dead = true;
                    break;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
}

/// Bounds of the next complete line at/after `from`: `(end, next)` where
/// `rbuf[from..end]` is the line (newline excluded) and `next` is where
/// the following line starts. Pure scan — the caller compacts the buffer
/// once per readiness round, not per line.
fn next_line_bounds(rbuf: &[u8], from: usize) -> Option<(usize, usize)> {
    let rel = rbuf[from..].iter().position(|&b| b == b'\n')?;
    Some((from + rel, from + rel + 1))
}

/// Turn a completion into its response JSON in the owning connection's
/// slot. Fallbacks here mirror what the threaded path's blocking calls
/// do when the sweeper is gone.
fn resolve_slot(
    conn: &mut Conn,
    token: u64,
    completion: Completion,
    front: &ShardedFront,
) {
    for slot in conn.slots.iter_mut() {
        let Slot::Waiting { token: t, kind } = slot else {
            continue;
        };
        if *t != token {
            continue;
        }
        let json = match (kind, completion) {
            (PendingKind::Predict { input, queued_at }, Completion::Done(out)) => {
                predict_response(out, input.len(), queued_at.elapsed().as_secs_f64())
            }
            (PendingKind::Predict { input, queued_at }, Completion::Dropped) => {
                // sweeper gone: direct same-precision computation, just
                // like BatchFront::predict's fallback — still identical
                // bits on the wire
                let steps = input.len();
                let out = front.model().predict(input);
                predict_response(out, steps, queued_at.elapsed().as_secs_f64())
            }
            (PendingKind::Stream, Completion::Done(outs)) => stream_response(outs),
            (PendingKind::Reset, Completion::Done(_)) => ok_response(),
            (PendingKind::Stream | PendingKind::Reset, Completion::Dropped) => {
                error_response(&anyhow!("batch front unavailable"))
            }
        };
        *slot = Slot::Ready(json);
        return;
    }
}

/// Write as much of the pending buffer as the socket accepts.
fn flush(conn: &mut Conn) {
    while conn.wpos < conn.wbuf.len() {
        match conn.sock.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_framing_handles_partial_multiple_and_empty_lines() {
        let buf = b"abc\ndef".to_vec();
        assert_eq!(next_line_bounds(&buf, 0), Some((3, 4)));
        assert_eq!(&buf[0..3], b"abc");
        // partial tail: no complete line yet
        assert_eq!(next_line_bounds(&buf, 4), None);
        let buf = b"abc\ndef\n\nx".to_vec();
        let (end1, next1) = next_line_bounds(&buf, 0).unwrap();
        assert_eq!(&buf[0..end1], b"abc");
        let (end2, next2) = next_line_bounds(&buf, next1).unwrap();
        assert_eq!(&buf[next1..end2], b"def");
        // empty line between newlines
        let (end3, next3) = next_line_bounds(&buf, next2).unwrap();
        assert_eq!(end3, next2, "empty line has zero length");
        assert_eq!(next_line_bounds(&buf, next3), None, "partial 'x' tail");
    }

    #[test]
    fn eventfd_signal_wakes_epoll_with_its_token() {
        let ep = Epoll::new().unwrap();
        let efd = EventFd::new().unwrap();
        ep.add(efd.fd, EPOLLIN, 9).unwrap();
        efd.signal();
        efd.signal(); // coalesces: still one readable event
        let mut events = vec![EpollEvent { events: 0, data: 0 }; 4];
        let n = ep.wait(&mut events).unwrap();
        assert_eq!(n, 1);
        let token = events[0].data;
        assert_eq!(token, 9);
        efd.drain_counter();
    }
}
