//! Shard-per-core serving: `S` independent [`BatchFront`] sweepers behind
//! one dispatch facade, turning the box into `cores × B` lanes.
//!
//! One `BatchFront` sweeper is single-core by design — every connection
//! funnels into one job queue drained by one thread, so one core does all
//! the arithmetic no matter how many the box has. The diagonal step is
//! embarrassingly parallel across lanes AND across users, and the SoA
//! planes already isolate lane state, so sharding is pure replication:
//! each shard owns its own sweeper thread, job queue, streaming-lane hub,
//! and pooled predict engines, and shares only the read-only
//! `Arc<Model>`. Nothing on the hot path crosses a shard boundary, so
//! there are no locks to contend — aggregate throughput scales with
//! shard count until memory bandwidth saturates.
//!
//! Dispatch policy:
//! * **streams** — each connection hashes (SplitMix64 of its connection
//!   key) to a *home shard* and keeps it for the connection's lifetime:
//!   per-connection state never migrates. The map is a pure function of
//!   the key, so identical keys always land on the same shard; the wire
//!   layer derives the key from the peer IP, which makes shard placement
//!   stable across reconnects (tested).
//! * **stateless predicts** — dealt to the least-loaded shard (smallest
//!   queue) with a rotating tie-break, so a burst fills all sweepers
//!   instead of queueing behind one.
//!
//! With `S = 1` the facade is exactly the PR-2 single-front server —
//! same sweeper, same arithmetic, bit-identical responses (tested).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock, Weak};
use std::time::Instant;

use anyhow::Result;

use super::cluster::{fnv1a, ClusterState};
use super::front::{BatchFront, LaneSnapshot, Reply, ReplySender};
use super::registry::{ModelId, ModelRegistry};
use super::Model;

/// Minimum occupancy skew (hottest minus coldest shard, in lanes) at
/// which [`ShardedFront::rebalance_once`] migrates lanes.
const REBALANCE_MIN_SKEW: usize = 2;
/// EWMA smoothing factor for the per-shard occupancy signal in `info`.
const EWMA_ALPHA: f64 = 0.2;
/// Most parked (standby-pushed, not yet adopted) lane snapshots
/// retained — beyond this, `migrate_in` refuses with `hub_full` so a
/// replica's memory stays bounded no matter how many primaries push.
const PARKED_MAX: usize = 1024;

/// A connection's mobile lane identity: the level of indirection that
/// makes live migration atomic. Connections hold an `Arc<LaneBinding>`
/// instead of a raw `(shard, lane)` pair and route every lane op
/// through [`ShardedFront::with_binding`], which resolves the current
/// home under the binding's lock. Migration holds that same lock across
/// its checkpoint → restore → re-home sequence, so ops submitted before
/// the move land on the source lane, ops after land on the target lane,
/// and nothing ever observes a half-moved lane — the FIFO shard queues
/// do the rest of the ordering, which is what makes a mid-stream
/// migration bit-invisible.
pub struct LaneBinding {
    /// Process-unique id (monotonic from 1) — names the lane in `info`,
    /// in standby pushes, and in drain-checkpoint spill files.
    id: u64,
    /// Current `(shard index, lane index)` home. Locked for the full
    /// duration of a migration.
    home: Mutex<(usize, usize)>,
    /// Per-replica dirty bits (bit `i` = standby replica `i` has not yet
    /// been shipped the latest state). Every state-mutating op sets ALL
    /// bits at once; each replica's pusher clears only its own, so the
    /// fan-out replicas lag independently. Idle lanes stay clean and
    /// cost the pushers nothing. Replica count is therefore capped at 64
    /// — far past any sane fan-out.
    dirty: AtomicU64,
    /// Per-replica in-flight bits (swapped-off dirty bit not yet
    /// confirmed by that replica) — counted in `standby_lag_lanes` so
    /// "lag 0" really means the replica has everything.
    pushing: AtomicU64,
    /// The binding's lane has been returned to its shard's free list;
    /// late ops answer `no_lane`.
    released: AtomicBool,
}

impl LaneBinding {
    /// Process-unique lane id (stable across migrations).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The shard currently homing this binding's lane.
    pub fn home_shard(&self) -> usize {
        self.home.lock().unwrap().0
    }

    /// The lane index on the current home shard.
    pub fn home_lane(&self) -> usize {
        self.home.lock().unwrap().1
    }

    /// Record a state mutation for the standby delta streams: every
    /// configured replica now lags this lane.
    pub fn mark_dirty(&self) {
        self.dirty.fetch_or(u64::MAX, Ordering::SeqCst);
    }

    /// Lane already returned to the free list (connection gone)?
    pub fn released(&self) -> bool {
        self.released.load(Ordering::SeqCst)
    }

    /// Claim replica `replica`'s dirty bit for a standby push. `true` =
    /// there is new state to ship to THAT replica (and the lane is now
    /// counted as mid-push for it); `false` = clean since its last
    /// push, ship nothing. Other replicas' bits are untouched.
    pub(crate) fn begin_push(&self, replica: usize) -> bool {
        let bit = 1u64 << (replica % 64);
        if self.dirty.fetch_and(!bit, Ordering::SeqCst) & bit == 0 {
            return false;
        }
        self.pushing.fetch_or(bit, Ordering::SeqCst);
        true
    }

    /// Finish replica `replica`'s push; a FAILED push re-marks the lane
    /// dirty for that replica so the delta is retried instead of lost.
    pub(crate) fn end_push(&self, replica: usize, ok: bool) {
        let bit = 1u64 << (replica % 64);
        if !ok {
            self.dirty.fetch_or(bit, Ordering::SeqCst);
        }
        self.pushing.fetch_and(!bit, Ordering::SeqCst);
    }

    /// Dirty or mid-push under `mask` — some replica in the mask does
    /// not yet hold this lane's latest state.
    fn lagging_under(&self, mask: u64) -> bool {
        (self.dirty.load(Ordering::SeqCst) | self.pushing.load(Ordering::SeqCst))
            & mask
            != 0
    }
}

/// `S` independent micro-batching fronts plus the dispatch policy.
pub struct ShardedFront {
    shards: Vec<Arc<BatchFront>>,
    /// The multi-tenant model registry, shared by every shard's sweeper
    /// (`None` = classic single-model serving; the zero-tenant path).
    registry: Option<Arc<ModelRegistry>>,
    /// Rotating offset for the least-loaded predict deal's tie-break.
    rr: AtomicUsize,
    /// Every live lane binding (weak: a dropped connection's binding
    /// prunes itself) — the migration, rebalance, standby-push, and
    /// drain-spill work lists.
    bindings: Mutex<Vec<Weak<LaneBinding>>>,
    /// Next binding id (ids start at 1; 0 is never a valid lane id).
    next_binding_id: AtomicU64,
    /// Lanes moved by [`Self::migrate_binding`] since start.
    lanes_migrated: AtomicU64,
    /// Per-shard occupancy EWMA (f64 bit patterns; see
    /// [`Self::update_occupancy_ewma`]).
    occ_ewma: Vec<AtomicU64>,
    /// Lane snapshots pushed by a primary (`migrate_in` with both id and
    /// checkpoint) awaiting adoption — the warm-standby parking lot.
    /// Parked lanes occupy NO hub lane: a replica can hold state for
    /// more primaries than it has lanes, paying a lane only on adopt.
    parked: Mutex<HashMap<u64, LaneSnapshot>>,
    /// Standby replica count (0 = no fan-out configured).
    replicas: AtomicUsize,
    /// Dirty-bit mask covering the configured replicas. Defaults to ALL
    /// bits so a server without a pusher keeps the legacy semantics
    /// (`standby_lag_lanes` counts every dirty lane); `set_replicas`
    /// narrows it to the low N bits so lag only measures real replicas.
    replica_mask: AtomicU64,
    /// Cluster membership view (consistent-hash ring + failure
    /// detector), set once by `serve_on_opts` when `--peers` is given —
    /// both transports' ownership guards read it from here.
    cluster: OnceLock<Arc<ClusterState>>,
    /// Wire-path observability, set once by the event-loop transport
    /// (`--poll-threads`); `None` on the threaded transport, so `info`
    /// omits the poll fields there.
    poll_stats: OnceLock<Arc<PollStats>>,
}

/// Counters the event-loop transport publishes through `info`: the
/// poll-thread count, per-thread readiness-round totals (a stuck thread
/// shows as a frozen counter while its siblings advance), and how many
/// connections negotiated the binary frame protocol.
pub struct PollStats {
    rounds: Vec<AtomicU64>,
    binary_conns: AtomicU64,
}

impl PollStats {
    pub fn new(threads: usize) -> Self {
        Self {
            rounds: (0..threads.max(1)).map(|_| AtomicU64::new(0)).collect(),
            binary_conns: AtomicU64::new(0),
        }
    }

    /// Configured poll-thread count.
    pub fn threads(&self) -> usize {
        self.rounds.len()
    }

    /// One epoll readiness round completed on thread `i`.
    pub fn bump_round(&self, i: usize) {
        if let Some(r) = self.rounds.get(i) {
            r.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Per-thread readiness-round totals.
    pub fn rounds(&self) -> Vec<u64> {
        self.rounds.iter().map(|r| r.load(Ordering::Relaxed)).collect()
    }

    /// A connection upgraded to binary frames.
    pub fn note_binary_conn(&self) {
        self.binary_conns.fetch_add(1, Ordering::Relaxed);
    }

    /// Total binary-upgraded connections since start.
    pub fn binary_conns(&self) -> u64 {
        self.binary_conns.load(Ordering::Relaxed)
    }
}

impl ShardedFront {
    /// Spawn `shards` sweepers (≥ 1; clamped) with immediate drain.
    pub fn start(model: Arc<Model>, shards: usize) -> Arc<Self> {
        Self::start_with_holdoff(model, shards, 0)
    }

    /// Spawn `shards` sweepers, each with the given hold-off window (µs).
    pub fn start_with_holdoff(
        model: Arc<Model>,
        shards: usize,
        holdoff_us: u64,
    ) -> Arc<Self> {
        Self::start_configured(model, shards, holdoff_us, usize::MAX)
    }

    /// [`Self::start_with_holdoff`] with a per-shard trainer memory
    /// budget in bytes (`usize::MAX` = unlimited). Each shard's hub
    /// enforces the budget independently — lanes never migrate between
    /// shards, so a per-shard cap is a per-connection-population cap.
    pub fn start_configured(
        model: Arc<Model>,
        shards: usize,
        holdoff_us: u64,
        trainer_budget: usize,
    ) -> Arc<Self> {
        Self::start_registry(model, None, shards, holdoff_us, trainer_budget, false)
    }

    /// The full constructor: [`Self::start_configured`] plus the
    /// multi-tenant model registry (shared by every shard — tenants are
    /// process-wide, lanes are per-shard) and opt-in sweeper core
    /// pinning. With `pin_cores`, shard `i`'s sweeper thread pins itself
    /// to core `i mod cores` before its first sweep, so each sweeper's
    /// working set (hub planes + pooled engines) stays resident in one
    /// core's cache hierarchy instead of bouncing on scheduler whims.
    pub fn start_registry(
        model: Arc<Model>,
        registry: Option<Arc<ModelRegistry>>,
        shards: usize,
        holdoff_us: u64,
        trainer_budget: usize,
        pin_cores: bool,
    ) -> Arc<Self> {
        let shards = shards.max(1);
        let cores = std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(1);
        let fronts = (0..shards)
            .map(|i| {
                BatchFront::start_full(
                    Arc::clone(&model),
                    registry.clone(),
                    holdoff_us,
                    format!("lr-shard-{i}-sweeper"),
                    trainer_budget,
                    pin_cores.then_some(i % cores),
                )
            })
            .collect();
        Arc::new(Self {
            shards: fronts,
            registry,
            rr: AtomicUsize::new(0),
            bindings: Mutex::new(Vec::new()),
            next_binding_id: AtomicU64::new(1),
            lanes_migrated: AtomicU64::new(0),
            occ_ewma: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            parked: Mutex::new(HashMap::new()),
            replicas: AtomicUsize::new(0),
            replica_mask: AtomicU64::new(u64::MAX),
            cluster: OnceLock::new(),
            poll_stats: OnceLock::new(),
        })
    }

    /// Attach the event-loop transport's poll stats (once; later calls
    /// ignored — one transport serves a front for its lifetime).
    pub fn set_poll_stats(&self, s: Arc<PollStats>) {
        let _ = self.poll_stats.set(s);
    }

    /// The event-loop poll stats, when that transport serves this front.
    pub fn poll_stats(&self) -> Option<&Arc<PollStats>> {
        self.poll_stats.get()
    }

    /// A connection negotiated the binary frame protocol (no-op on the
    /// threaded transport, which publishes no poll stats).
    pub fn note_binary_conn(&self) {
        if let Some(s) = self.poll_stats.get() {
            s.note_binary_conn();
        }
    }

    /// Declare the standby fan-out width (N replicas, capped at 64).
    /// Called once by `serve_on_opts` before the pusher starts.
    pub fn set_replicas(&self, n: usize) {
        let n = n.min(64);
        self.replicas.store(n, Ordering::SeqCst);
        let mask = if n >= 64 { u64::MAX } else { (1u64 << n) - 1 };
        self.replica_mask.store(mask, Ordering::SeqCst);
    }

    /// Configured standby replica count.
    pub fn standby_replicas(&self) -> usize {
        self.replicas.load(Ordering::SeqCst)
    }

    /// Switch every shard between the fixed hold-off window and
    /// autotuned mode (`--holdoff-auto`).
    pub fn set_holdoff_auto(&self, on: bool) {
        for s in &self.shards {
            s.set_holdoff_auto(on);
        }
    }

    /// Attach the cluster membership view (once; later calls ignored).
    pub fn set_cluster(&self, c: Arc<ClusterState>) {
        let _ = self.cluster.set(c);
    }

    /// The cluster membership view, when this node runs clustered.
    pub fn cluster(&self) -> Option<&Arc<ClusterState>> {
        self.cluster.get()
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Direct access to shard `i` (streaming lanes live on a shard).
    pub fn shard(&self, i: usize) -> &Arc<BatchFront> {
        &self.shards[i]
    }

    /// The model every shard serves.
    pub fn model(&self) -> &Arc<Model> {
        self.shards[0].model()
    }

    /// The multi-tenant model registry, when one is configured.
    pub fn registry(&self) -> Option<&Arc<ModelRegistry>> {
        self.registry.as_ref()
    }

    /// Bound-lane counts per model, aggregated across shards (sorted by
    /// model id; `info`'s per-tenant occupancy view). Free lanes are not
    /// counted, so a tenant-free server reports only the base model's
    /// in-use lanes.
    pub fn lane_counts_by_model(&self) -> Vec<(ModelId, usize)> {
        let mut agg: Vec<(ModelId, usize)> = Vec::new();
        for s in &self.shards {
            for (model, n) in s.lane_counts_by_model() {
                match agg.iter_mut().find(|(m, _)| *m == model) {
                    Some((_, total)) => *total += n,
                    None => agg.push((model, n)),
                }
            }
        }
        agg.sort_unstable_by_key(|&(m, _)| m);
        agg
    }

    /// Per-shard sweeper core pins (`None` = unpinned) — all `None`
    /// unless `--pin-cores` was given and `sched_setaffinity` succeeded.
    pub fn pinned_cores(&self) -> Vec<Option<usize>> {
        self.shards.iter().map(|s| s.pinned_core()).collect()
    }

    /// Home shard for a connection key: a pure function of the key
    /// (SplitMix64, uniform across shards) — the same key maps to the
    /// same shard on every call, on every run, at the same shard count.
    /// The wire layer derives the key from the peer IP (not the
    /// ephemeral port), so a reconnecting client hashes back to its
    /// previous home shard; any caller-supplied persistent identity gets
    /// the same stability from this function.
    pub fn shard_for_key(&self, key: u64) -> usize {
        (crate::rng::splitmix64_mix(key) % self.shards.len() as u64) as usize
    }

    /// The home front for a connection key.
    pub fn home(&self, key: u64) -> &Arc<BatchFront> {
        &self.shards[self.shard_for_key(key)]
    }

    /// Least-loaded shard for a stateless job, rotating the scan start so
    /// ties spread round-robin instead of piling on shard 0.
    fn pick_shard(&self) -> &Arc<BatchFront> {
        let n = self.shards.len();
        if n == 1 {
            return &self.shards[0];
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_depth = self.shards[start].queue_depth();
        for off in 1..n {
            let i = (start + off) % n;
            let d = self.shards[i].queue_depth();
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        &self.shards[best]
    }

    /// Stateless prediction, dealt to the least-loaded shard. Falls back
    /// to a direct same-precision computation if that shard's sweeper is
    /// gone (inside [`BatchFront::predict`]).
    pub fn predict(&self, input: Vec<f64>) -> Vec<f64> {
        self.pick_shard().predict(input)
    }

    /// [`Self::predict`] under a client deadline: shed or expired jobs
    /// answer the typed `overloaded` / `deadline_exceeded` error.
    pub fn predict_deadline(
        &self,
        input: Vec<f64>,
        deadline: Option<Instant>,
    ) -> Result<Vec<f64>> {
        self.pick_shard().predict_deadline(input, deadline)
    }

    /// Fan-out form of [`Self::predict`]: enqueue on the least-loaded
    /// shard and return the reply channel without blocking (benches and
    /// batch submitters collect the receivers afterwards).
    pub fn predict_async(
        &self,
        input: Vec<f64>,
    ) -> Option<mpsc::Receiver<super::front::Reply>> {
        self.pick_shard().predict_async(input)
    }

    /// Least-loaded-deal predict with an arbitrary reply sink — the
    /// event loop's form: it passes an `EventReply`, never blocks, and a
    /// refused job (sweeper gone) still resolves through the reply's
    /// `Dropped` completion, so the return value only reports whether
    /// the job was queued. The input `Arc` lets the caller keep its
    /// fallback copy without cloning the data.
    pub(crate) fn submit_predict_dealt(
        &self,
        input: Arc<Vec<f64>>,
        reply: super::front::ReplySender,
    ) -> bool {
        self.submit_predict_dealt_deadline(input, reply, None)
    }

    /// [`Self::submit_predict_dealt`] with a client deadline.
    pub(crate) fn submit_predict_dealt_deadline(
        &self,
        input: Arc<Vec<f64>>,
        reply: super::front::ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        self.pick_shard().submit_predict_deadline(input, reply, deadline)
    }

    /// Model-addressed [`Self::predict_deadline`]: still dealt to the
    /// least-loaded shard — the registry is process-wide, so any shard
    /// serves any tenant's stateless predicts.
    pub fn predict_deadline_model(
        &self,
        model: ModelId,
        input: Vec<f64>,
        deadline: Option<Instant>,
    ) -> Result<Vec<f64>> {
        self.pick_shard().predict_deadline_model(model, input, deadline)
    }

    /// Model-addressed [`Self::submit_predict_dealt_deadline`] — the
    /// event loop's tenant predict path.
    pub(crate) fn submit_predict_dealt_model(
        &self,
        model: ModelId,
        input: Arc<Vec<f64>>,
        reply: super::front::ReplySender,
        deadline: Option<Instant>,
    ) -> bool {
        self.pick_shard()
            .submit_predict_model(model, input, reply, deadline)
    }

    /// Streaming step(s) on a lane of shard `shard_idx`.
    pub fn stream(
        &self,
        shard_idx: usize,
        lane: usize,
        input: Vec<f64>,
    ) -> Result<Vec<f64>> {
        self.shards[shard_idx].stream(lane, input)
    }

    // -----------------------------------------------------------------
    // lane bindings: acquisition, migration, rebalance, standby parking
    // -----------------------------------------------------------------

    /// Acquire a lane on `shard_idx` wrapped in a mobile [`LaneBinding`]
    /// (the connection-facing form of `acquire_lane`: everything routed
    /// through the binding survives a live migration). `None` when the
    /// shard's hub is full.
    pub fn acquire_binding(&self, shard_idx: usize) -> Option<Arc<LaneBinding>> {
        let lane = self.shards[shard_idx].acquire_lane()?;
        let b = Arc::new(LaneBinding {
            id: self.next_binding_id.fetch_add(1, Ordering::Relaxed),
            home: Mutex::new((shard_idx, lane)),
            dirty: AtomicU64::new(0),
            pushing: AtomicU64::new(0),
            released: AtomicBool::new(false),
        });
        let mut reg = self.bindings.lock().unwrap();
        reg.retain(|w| w.strong_count() > 0);
        reg.push(Arc::downgrade(&b));
        Some(b)
    }

    /// Return the binding's lane to its home shard's free list
    /// (idempotent). Serializes with migration on the home lock, so a
    /// lane is never released mid-move.
    pub fn release_binding(&self, b: &Arc<LaneBinding>) {
        let home = b.home.lock().unwrap();
        if b.released.swap(true, Ordering::SeqCst) {
            return;
        }
        let (shard, lane) = *home;
        self.shards[shard].release_lane(lane);
    }

    /// Every live (upgradeable, unreleased) binding; prunes dead weak
    /// entries as a side effect.
    pub fn live_bindings(&self) -> Vec<Arc<LaneBinding>> {
        let mut reg = self.bindings.lock().unwrap();
        reg.retain(|w| w.strong_count() > 0);
        reg.iter()
            .filter_map(Weak::upgrade)
            .filter(|b| !b.released())
            .collect()
    }

    /// Run `f` against the binding's CURRENT home `(front, lane)` under
    /// the binding lock — every lane op for a bound connection goes
    /// through here, so submissions serialize with migration: an op
    /// submitted before a move lands on the source lane (FIFO queue, the
    /// migration checkpoint drains after it), an op after lands on the
    /// target. Holding the lock across a blocking sync op is fine: only
    /// migration and other ops on the SAME binding wait, and sweepers
    /// never take binding locks.
    pub fn with_binding<R>(
        &self,
        b: &LaneBinding,
        f: impl FnOnce(&BatchFront, usize) -> R,
    ) -> R {
        let home = b.home.lock().unwrap();
        let (shard, lane) = *home;
        f(&self.shards[shard], lane)
    }

    /// Synchronous checkpoint of a binding's lane under its home lock
    /// (the standby pusher's and drain spill's snapshot source).
    pub fn checkpoint_binding(
        &self,
        b: &LaneBinding,
    ) -> std::result::Result<LaneSnapshot, &'static str> {
        let home = b.home.lock().unwrap();
        if b.released() {
            return Err("no_lane");
        }
        let (shard, lane) = *home;
        Self::sync_checkpoint(&self.shards[shard], lane)
    }

    fn sync_checkpoint(
        front: &BatchFront,
        lane: usize,
    ) -> std::result::Result<LaneSnapshot, &'static str> {
        let (tx, rx) = mpsc::channel();
        if !front.submit_checkpoint(lane, ReplySender::Chan(tx)) {
            return Err("unavailable");
        }
        match rx.recv() {
            Ok(Reply::Snap(s)) => Ok(*s),
            Ok(Reply::Err(code)) => Err(code),
            _ => Err("unavailable"),
        }
    }

    /// Live lane migration: checkpoint the binding's lane on its source
    /// shard, restore it onto a fresh lane of `target` (coldest shard
    /// when `None`), atomically re-home the binding, and free the source
    /// lane. The home lock is held for the whole sequence, so concurrent
    /// ops on this binding simply queue behind the move and continue on
    /// the target — mid-stream migration is bit-invisible (the snapshot
    /// round-trip is exact, and a refused restore leaves the old home
    /// fully intact). Returns `(target shard, target lane, active
    /// version)` or the typed error code.
    pub fn migrate_binding(
        &self,
        b: &Arc<LaneBinding>,
        target: Option<usize>,
    ) -> std::result::Result<(usize, usize, u64), &'static str> {
        let mut home = b.home.lock().unwrap();
        if b.released() {
            return Err("no_lane");
        }
        let (src, src_lane) = *home;
        let dst = match target {
            Some(d) if d < self.shards.len() => d,
            Some(_) => return Err("unknown_lane"),
            None => self.coldest_shard_except(src),
        };
        let snap = Self::sync_checkpoint(&self.shards[src], src_lane)?;
        let dst_front = &self.shards[dst];
        let dst_lane = dst_front.acquire_lane().ok_or("hub_full")?;
        // carry the tenant binding with the lane BEFORE submitting the
        // restore, so the restore (and everything after it) routes to
        // the same model's hub on the target shard; the failure paths
        // below go through `release_lane`, which clears the binding.
        dst_front
            .bind_lane_model(dst_lane, self.shards[src].lane_model_of(src_lane));
        let (tx, rx) = mpsc::channel();
        if !dst_front.submit_restore(dst_lane, Box::new(snap), ReplySender::Chan(tx))
        {
            dst_front.release_lane(dst_lane);
            return Err("unavailable");
        }
        let version = match rx.recv() {
            Ok(Reply::Vals(v)) => v.first().copied().unwrap_or(0.0) as u64,
            Ok(Reply::Err(code)) => {
                dst_front.release_lane(dst_lane);
                return Err(code);
            }
            _ => {
                dst_front.release_lane(dst_lane);
                return Err("unavailable");
            }
        };
        // the move is committed: free the source lane, re-home, count
        self.shards[src].release_lane(src_lane);
        *home = (dst, dst_lane);
        b.mark_dirty();
        self.lanes_migrated.fetch_add(1, Ordering::Relaxed);
        Ok((dst, dst_lane, version))
    }

    /// The least-occupied shard (fewest lanes in use, queue depth as the
    /// tie-break), preferring any shard over `except` when there is a
    /// choice — the migration target policy.
    fn coldest_shard_except(&self, except: usize) -> usize {
        let mut best = except;
        let mut best_key = (usize::MAX, usize::MAX);
        for (i, s) in self.shards.iter().enumerate() {
            if i == except {
                continue;
            }
            let key = (s.lanes_in_use(), s.queue_depth());
            if key < best_key {
                best = i;
                best_key = key;
            }
        }
        best
    }

    /// One rebalance round: refresh the occupancy EWMAs, and when the
    /// hottest shard holds at least `REBALANCE_MIN_SKEW` more lanes than
    /// the coldest, migrate half the skew from hottest to coldest.
    /// Returns the number of lanes moved. Driven by the `--rebalance`
    /// policy thread; callable directly for deterministic tests.
    pub fn rebalance_once(&self) -> usize {
        self.update_occupancy_ewma();
        if self.shards.len() < 2 {
            return 0;
        }
        let occ: Vec<usize> =
            self.shards.iter().map(|s| s.lanes_in_use()).collect();
        let hot = (0..occ.len()).max_by_key(|&i| occ[i]).unwrap();
        let cold = (0..occ.len()).min_by_key(|&i| occ[i]).unwrap();
        let skew = occ[hot].saturating_sub(occ[cold]);
        if skew < REBALANCE_MIN_SKEW {
            return 0;
        }
        let quota = skew / 2;
        let mut moved = 0;
        for b in self.live_bindings() {
            if moved >= quota {
                break;
            }
            if b.home_shard() == hot
                && self.migrate_binding(&b, Some(cold)).is_ok()
            {
                moved += 1;
            }
        }
        moved
    }

    /// Fold the instantaneous per-shard lane occupancy into the EWMAs
    /// and return them (called by the rebalancer tick and by `info`).
    pub fn update_occupancy_ewma(&self) -> Vec<f64> {
        self.shards
            .iter()
            .zip(&self.occ_ewma)
            .map(|(s, cell)| {
                let occ = s.lanes_in_use() as f64;
                let old = f64::from_bits(cell.load(Ordering::Relaxed));
                let new = EWMA_ALPHA * occ + (1.0 - EWMA_ALPHA) * old;
                cell.store(new.to_bits(), Ordering::Relaxed);
                new
            })
            .collect()
    }

    /// Lanes moved by migration since start (metrics; `info`).
    pub fn lanes_migrated(&self) -> u64 {
        self.lanes_migrated.load(Ordering::Relaxed)
    }

    /// Jobs shed with `overloaded` across shards (metrics; `info`).
    pub fn jobs_shed_total(&self) -> u64 {
        self.shards.iter().map(|s| s.jobs_shed()).sum()
    }

    /// Jobs refused with `deadline_exceeded` across shards.
    pub fn deadline_misses_total(&self) -> u64 {
        self.shards.iter().map(|s| s.deadline_misses()).sum()
    }

    /// Live bindings whose latest state SOME standby replica does not
    /// yet hold (dirty or mid-push) — `info`'s `standby_lag_lanes`.
    /// With fan-out configured this is the worst case over replicas:
    /// `0` means EVERY replica holds every lane's latest state.
    pub fn standby_lag_lanes(&self) -> usize {
        let mask = self.replica_mask.load(Ordering::SeqCst);
        self.live_bindings()
            .iter()
            .filter(|b| b.lagging_under(mask))
            .count()
    }

    /// [`Self::standby_lag_lanes`] for ONE replica of the fan-out —
    /// `info`'s `standby_lag_per_replica` array.
    pub fn standby_lag_lanes_for(&self, replica: usize) -> usize {
        let bit = 1u64 << (replica % 64);
        self.live_bindings()
            .iter()
            .filter(|b| b.lagging_under(bit))
            .count()
    }

    /// Park a pushed lane snapshot under the primary's lane id (replaces
    /// any previous delta for the id — the delta stream is
    /// last-write-wins by construction). `false` when the bounded
    /// parking lot is full.
    pub fn park(&self, id: u64, snap: LaneSnapshot) -> bool {
        let mut p = self.parked.lock().unwrap();
        if p.len() >= PARKED_MAX && !p.contains_key(&id) {
            return false;
        }
        p.insert(id, snap);
        true
    }

    /// A clone of the parked snapshot for `id`, if any (adoption peeks
    /// first and unparks only after the restore succeeds).
    pub fn parked_snapshot(&self, id: u64) -> Option<LaneSnapshot> {
        self.parked.lock().unwrap().get(&id).cloned()
    }

    /// Drop the parked snapshot for `id` (after a successful adoption).
    pub fn unpark(&self, id: u64) {
        self.parked.lock().unwrap().remove(&id);
    }

    /// Parked (pushed, unadopted) lane snapshots held (metrics; `info`).
    pub fn parked_lanes(&self) -> usize {
        self.parked.lock().unwrap().len()
    }

    /// Checkpoint each binding and write it to `dir/lane-<id>.json`
    /// (creating `dir`) — the `--drain-checkpoint` spill. Each file is
    /// two lines — the compact snapshot JSON, then an FNV-1a checksum of
    /// the JSON bytes (`fnv1a:<16 hex>`) — written to a `.tmp` sibling
    /// and atomically renamed into place, so a successor adopting the
    /// spill can NEVER observe a torn half-written snapshot: it sees the
    /// old file, the new file, or (checksum mismatch / missing line) a
    /// detectably corrupt one it must refuse. Failures are reported per
    /// lane and skipped: a poisoned lane must not abort the drain of
    /// healthy ones. Returns the number of lanes spilled.
    pub fn spill_bindings(
        &self,
        bindings: &[Arc<LaneBinding>],
        dir: &std::path::Path,
    ) -> usize {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("drain-checkpoint: cannot create {}: {e}", dir.display());
            return 0;
        }
        let mut spilled = 0;
        for b in bindings {
            match self.checkpoint_binding(b) {
                Ok(snap) => {
                    let path = dir.join(format!("lane-{}.json", b.id()));
                    let json = super::wire::snapshot_to_json(&snap)
                        .to_string_compact();
                    let text = format!(
                        "{json}\nfnv1a:{:016x}\n",
                        fnv1a(json.as_bytes())
                    );
                    let tmp = dir.join(format!("lane-{}.json.tmp", b.id()));
                    let wrote = std::fs::write(&tmp, text)
                        .and_then(|()| std::fs::rename(&tmp, &path));
                    match wrote {
                        Ok(()) => spilled += 1,
                        Err(e) => {
                            let _ = std::fs::remove_file(&tmp);
                            eprintln!(
                                "drain-checkpoint: write {} failed: {e}",
                                path.display()
                            );
                        }
                    }
                }
                Err(code) => eprintln!(
                    "drain-checkpoint: lane {} not spilled ({code})",
                    b.id()
                ),
            }
        }
        spilled
    }

    /// Read one spilled lane file back, verifying its checksum line, and
    /// return the snapshot JSON text (first line). A truncated,
    /// tampered, or checksum-less file is a typed error — the successor
    /// tooling's integrity gate before it replays the snapshot through
    /// `restore`/`migrate_in`.
    pub fn read_spilled_lane(path: &std::path::Path) -> Result<String> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        let json = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("spill file is empty"))?;
        let sum_line = lines.next().ok_or_else(|| {
            anyhow::anyhow!("spill file has no checksum line (truncated?)")
        })?;
        let want = sum_line.strip_prefix("fnv1a:").ok_or_else(|| {
            anyhow::anyhow!("spill checksum line is malformed: {sum_line:?}")
        })?;
        let got = format!("{:016x}", fnv1a(json.as_bytes()));
        if got != want {
            anyhow::bail!(
                "spill checksum mismatch: file says fnv1a:{want}, \
                 content hashes to fnv1a:{got}"
            );
        }
        Ok(json.to_string())
    }

    /// Per-shard queue depths (metrics; `info`).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.queue_depth()).collect()
    }

    /// Per-shard sweep counts (metrics; `info`).
    pub fn sweep_counts(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.sweep_count()).collect()
    }

    /// Total queued jobs across shards.
    pub fn queue_depth_total(&self) -> usize {
        self.queue_depths().iter().sum()
    }

    /// Total sweep rounds across shards.
    pub fn sweep_count_total(&self) -> u64 {
        self.sweep_counts().iter().sum()
    }

    /// Shut every shard down (idempotent). Each front drains its queued
    /// jobs before its sweeper exits, so no accepted job is dropped.
    pub fn shutdown(&self) {
        for s in &self.shards {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{make_model, make_model_f32};
    use super::*;
    use crate::tasks::mso::MsoTask;

    #[test]
    fn shard_hash_is_stable_and_covers_all_shards() {
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 4);
        let mut hit = [false; 4];
        for key in 0..256u64 {
            let s = front.shard_for_key(key);
            assert!(s < 4);
            hit[s] = true;
            // stability: the assignment is a pure function of the key —
            // a reconnect (same key, later in time) lands on the same
            // shard, as does a fresh facade over the same shard count
            assert_eq!(s, front.shard_for_key(key));
        }
        assert!(hit.iter().all(|h| *h), "256 keys must cover 4 shards");
        // a second sharded front (server restart) assigns identically
        let front2 = ShardedFront::start(Arc::clone(&model), 4);
        for key in 0..64u64 {
            assert_eq!(front.shard_for_key(key), front2.shard_for_key(key));
        }
        front.shutdown();
        front2.shutdown();
    }

    #[test]
    fn cross_shard_lanes_are_isolated() {
        // two streaming connections on DIFFERENT shards: interleaved
        // requests must each reproduce their solo trajectory exactly
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 2);
        let task = MsoTask::new(1);
        let lane0 = front.shard(0).acquire_lane().unwrap();
        let lane1 = front.shard(1).acquire_lane().unwrap();
        let in0 = &task.input[..40];
        let in1 = &task.input[150..185];
        let mut got0 = front.stream(0, lane0, in0[..13].to_vec()).unwrap();
        let mut got1 = front.stream(1, lane1, in1[..9].to_vec()).unwrap();
        got0.extend(front.stream(0, lane0, in0[13..].to_vec()).unwrap());
        got1.extend(front.stream(1, lane1, in1[9..].to_vec()).unwrap());
        for (got, input) in [(got0, in0), (got1, in1)] {
            let want = model.predict(input);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() == 0.0,
                    "cross-shard stream diverged: {a} vs {b}"
                );
            }
        }
        front.shard(0).release_lane(lane0);
        front.shard(1).release_lane(lane1);
        front.shutdown();
    }

    #[test]
    fn single_shard_bit_identical_to_batch_front() {
        // `--shards 1` must reproduce the PR-2 single-front server
        // bit-exactly, at both precisions, on both predicts and streams
        for model in [Arc::new(make_model()), Arc::new(make_model_f32())] {
            let sharded = ShardedFront::start(Arc::clone(&model), 1);
            let plain = BatchFront::start(Arc::clone(&model));
            let task = MsoTask::new(2);
            for i in 0..4 {
                let input = task.input[i * 9..i * 9 + 28 + i].to_vec();
                let a = sharded.predict(input.clone());
                let b = plain.predict(input);
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert!(
                        (x - y).abs() == 0.0,
                        "shards=1 predict != BatchFront: {x} vs {y}"
                    );
                }
            }
            // streaming: same lane, same chunks, same bits
            let ls = sharded.shard(0).acquire_lane().unwrap();
            let lp = plain.acquire_lane().unwrap();
            let input = &task.input[..44];
            let mut got_s = sharded.stream(0, ls, input[..20].to_vec()).unwrap();
            got_s.extend(sharded.stream(0, ls, input[20..].to_vec()).unwrap());
            let mut got_p = plain.stream(lp, input[..20].to_vec()).unwrap();
            got_p.extend(plain.stream(lp, input[20..].to_vec()).unwrap());
            assert_eq!(got_s.len(), got_p.len());
            for (x, y) in got_s.iter().zip(&got_p) {
                assert!(
                    (x - y).abs() == 0.0,
                    "shards=1 stream != BatchFront: {x} vs {y}"
                );
            }
            sharded.shutdown();
            plain.shutdown();
        }
    }

    #[test]
    fn shutdown_drains_all_shard_queues() {
        // jobs accepted before shutdown must all be answered — shutdown
        // wakes every sweeper, which drains its queue before exiting
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 3);
        let task = MsoTask::new(2);
        let inputs: Vec<Vec<f64>> = (0..12)
            .map(|i| task.input[i * 7..i * 7 + 20 + i].to_vec())
            .collect();
        let replies: Vec<_> = inputs
            .iter()
            .map(|input| {
                front
                    .predict_async(input.clone())
                    .expect("front accepts before shutdown")
            })
            .collect();
        front.shutdown();
        for (input, rx) in inputs.iter().zip(replies) {
            let got = match rx.recv().expect("queued job answered during drain") {
                super::super::front::Reply::Vals(v) => v,
                other => panic!("expected values, got {other:?}"),
            };
            let want = model.predict(input);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() == 0.0);
            }
        }
        assert_eq!(front.queue_depth_total(), 0, "queues drained");
        front.shutdown(); // idempotent
    }

    #[test]
    fn migrate_binding_is_bit_invisible_mid_stream_at_both_precisions() {
        // the tentpole contract, in-process: stream half, migrate the
        // lane to the other shard, stream the rest — bit-identical to
        // an unmigrated twin, with trainer and committed readout along
        for model in [Arc::new(make_model()), Arc::new(make_model_f32())] {
            let front = ShardedFront::start(Arc::clone(&model), 2);
            let task = MsoTask::new(1);
            let input = &task.input[..60];
            let target: Vec<f64> =
                input.iter().map(|x| 0.5 - 2.0 * x).collect();
            // unmigrated twin: train 60 rows, commit, stream 40 more
            let t = front.acquire_binding(0).unwrap();
            assert_eq!(
                front
                    .with_binding(&t, |s, l| s.train(
                        l,
                        input.to_vec(),
                        target.clone()
                    ))
                    .unwrap(),
                60
            );
            assert_eq!(
                front.with_binding(&t, |s, l| s.commit(l, 1e-2)).unwrap(),
                1
            );
            let reference = front
                .with_binding(&t, |s, l| s.stream(l, task.input[60..100].to_vec()))
                .unwrap();
            // migrating lane: same history split around a live move
            let b = front.acquire_binding(0).unwrap();
            assert_eq!(
                front
                    .with_binding(&b, |s, l| s.train(
                        l,
                        input[..30].to_vec(),
                        target[..30].to_vec()
                    ))
                    .unwrap(),
                30
            );
            let (dst, _, v) = front.migrate_binding(&b, Some(1)).unwrap();
            assert_eq!(dst, 1);
            assert_eq!(v, 0, "no committed version yet");
            assert_eq!(b.home_shard(), 1);
            assert_eq!(front.lanes_migrated(), 1);
            assert_eq!(
                front
                    .with_binding(&b, |s, l| s.train(
                        l,
                        input[30..].to_vec(),
                        target[30..].to_vec()
                    ))
                    .unwrap(),
                60,
                "trainer rows must survive the move"
            );
            assert_eq!(
                front.with_binding(&b, |s, l| s.commit(l, 1e-2)).unwrap(),
                1
            );
            let got = front
                .with_binding(&b, |s, l| s.stream(l, task.input[60..100].to_vec()))
                .unwrap();
            assert_eq!(
                got, reference,
                "migrated lane diverged from the unmigrated twin"
            );
            // the source lane was freed: shard 0 is back to one lane
            assert_eq!(front.shard(0).lanes_in_use(), 1);
            assert_eq!(front.shard(1).lanes_in_use(), 1);
            // a migrate to an out-of-range shard is a typed refusal
            assert_eq!(
                front.migrate_binding(&b, Some(9)).unwrap_err(),
                "unknown_lane"
            );
            front.release_binding(&b);
            front.release_binding(&t);
            // released bindings refuse further moves, typed
            assert_eq!(front.migrate_binding(&b, None).unwrap_err(), "no_lane");
            assert_eq!(front.shard(0).lanes_in_use(), 0);
            assert_eq!(front.shard(1).lanes_in_use(), 0);
            front.shutdown();
        }
    }

    #[test]
    fn migration_carries_the_tenant_binding_with_the_lane() {
        // a lane bound to a registry tenant must keep serving THAT
        // tenant's model after a cross-shard move, and the aggregated
        // per-model lane counts must follow it
        use super::super::registry::{ModelRecipe, ModelRegistry, BASE_MODEL};
        let model = Arc::new(make_model());
        let registry = Arc::new(ModelRegistry::new(Arc::clone(&model), 4));
        let recipe = ModelRecipe::new(77, 40, 0.8, "uniform").unwrap();
        let (tenant, _) = registry.create(&recipe).unwrap();
        let tenant_model = registry.get(tenant).unwrap();
        let front = ShardedFront::start_registry(
            Arc::clone(&model),
            Some(Arc::clone(&registry)),
            2,
            0,
            usize::MAX,
            false,
        );
        let task = MsoTask::new(1);
        let input = &task.input[..60];

        // one tenant lane on shard 0, one base lane on shard 1
        let b = front.acquire_binding(0).unwrap();
        front.with_binding(&b, |s, l| s.bind_lane_model(l, tenant));
        let base = front.acquire_binding(1).unwrap();
        assert_eq!(
            front.lane_counts_by_model(),
            vec![(BASE_MODEL, 1), (tenant, 1)],
            "aggregated counts must see both shards' bindings"
        );

        let mut got = front
            .with_binding(&b, |s, l| s.stream(l, input[..25].to_vec()))
            .unwrap();
        let (dst, dst_lane, _) = front.migrate_binding(&b, Some(1)).unwrap();
        assert_eq!(dst, 1);
        assert_eq!(
            front.shard(1).lane_model_of(dst_lane),
            tenant,
            "the moved lane must stay bound to its tenant"
        );
        got.extend(
            front
                .with_binding(&b, |s, l| s.stream(l, input[25..].to_vec()))
                .unwrap(),
        );
        let want = tenant_model.predict(input);
        assert_eq!(
            got, want,
            "tenant stream must be bit-identical across the move"
        );
        // both bound lanes now live on shard 1; counts follow
        assert_eq!(
            front.lane_counts_by_model(),
            vec![(BASE_MODEL, 1), (tenant, 1)]
        );
        front.release_binding(&b);
        front.release_binding(&base);
        assert!(front.lane_counts_by_model().is_empty());
        front.shutdown();
    }

    #[test]
    fn rebalance_moves_half_the_skew_to_the_cold_shard() {
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 2);
        let task = MsoTask::new(1);
        // skewed population: 6 streaming lanes homed on shard 0
        let bindings: Vec<_> = (0..6)
            .map(|_| front.acquire_binding(0).unwrap())
            .collect();
        // give each lane distinct state so the moves carry real values
        for (i, b) in bindings.iter().enumerate() {
            let chunk = task.input[i * 5..i * 5 + 10].to_vec();
            front.with_binding(b, |s, l| s.stream(l, chunk)).unwrap();
        }
        assert_eq!(front.shard(0).lanes_in_use(), 6);
        assert_eq!(front.shard(1).lanes_in_use(), 0);
        let moved = front.rebalance_once();
        assert_eq!(moved, 3, "half the skew migrates");
        assert_eq!(front.shard(0).lanes_in_use(), 3);
        assert_eq!(front.shard(1).lanes_in_use(), 3);
        assert_eq!(front.lanes_migrated(), 3);
        // balanced: the next round must not churn
        assert_eq!(front.rebalance_once(), 0);
        // the moved lanes still continue their exact streams
        for (i, b) in bindings.iter().enumerate() {
            let chunk = task.input[i * 5 + 10..i * 5 + 20].to_vec();
            let got = front.with_binding(b, |s, l| s.stream(l, chunk)).unwrap();
            let want = model.predict(&task.input[i * 5..i * 5 + 20]);
            assert_eq!(got, want[10..], "lane {i} diverged after rebalance");
        }
        for b in &bindings {
            front.release_binding(b);
        }
        front.shutdown();
    }

    #[test]
    fn parked_snapshots_are_bounded_and_last_write_wins() {
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 1);
        let b = front.acquire_binding(0).unwrap();
        front
            .with_binding(&b, |s, l| s.stream(l, vec![0.1; 8]))
            .unwrap();
        let snap1 = front.checkpoint_binding(&b).unwrap();
        front
            .with_binding(&b, |s, l| s.stream(l, vec![0.2; 8]))
            .unwrap();
        let snap2 = front.checkpoint_binding(&b).unwrap();
        assert!(front.park(7, snap1.clone()));
        assert!(front.park(7, snap2.clone()), "re-push replaces in place");
        assert_eq!(front.parked_lanes(), 1);
        assert_eq!(front.parked_snapshot(7), Some(snap2));
        front.unpark(7);
        assert_eq!(front.parked_lanes(), 0);
        assert_eq!(front.parked_snapshot(7), None);
        front.release_binding(&b);
        front.shutdown();
    }

    #[test]
    fn least_loaded_deal_spreads_a_burst() {
        // with every queue empty the rotating tie-break spreads
        // consecutive predicts across shards — observable as sweeps on
        // more than one shard after a burst
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 2);
        let task = MsoTask::new(1);
        for i in 0..8 {
            let input = task.input[i * 5..i * 5 + 15].to_vec();
            let got = front.predict(input.clone());
            let want = model.predict(&input);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() == 0.0);
            }
        }
        let sweeps = front.sweep_counts();
        assert!(
            sweeps.iter().filter(|&&s| s > 0).count() >= 2,
            "8 sequential predicts on idle shards must touch both: {sweeps:?}"
        );
        front.shutdown();
    }
}
