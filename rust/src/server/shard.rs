//! Shard-per-core serving: `S` independent [`BatchFront`] sweepers behind
//! one dispatch facade, turning the box into `cores × B` lanes.
//!
//! One `BatchFront` sweeper is single-core by design — every connection
//! funnels into one job queue drained by one thread, so one core does all
//! the arithmetic no matter how many the box has. The diagonal step is
//! embarrassingly parallel across lanes AND across users, and the SoA
//! planes already isolate lane state, so sharding is pure replication:
//! each shard owns its own sweeper thread, job queue, streaming-lane hub,
//! and pooled predict engines, and shares only the read-only
//! `Arc<Model>`. Nothing on the hot path crosses a shard boundary, so
//! there are no locks to contend — aggregate throughput scales with
//! shard count until memory bandwidth saturates.
//!
//! Dispatch policy:
//! * **streams** — each connection hashes (SplitMix64 of its connection
//!   key) to a *home shard* and keeps it for the connection's lifetime:
//!   per-connection state never migrates. The map is a pure function of
//!   the key, so identical keys always land on the same shard; the wire
//!   layer derives the key from the peer IP, which makes shard placement
//!   stable across reconnects (tested).
//! * **stateless predicts** — dealt to the least-loaded shard (smallest
//!   queue) with a rotating tie-break, so a burst fills all sweepers
//!   instead of queueing behind one.
//!
//! With `S = 1` the facade is exactly the PR-2 single-front server —
//! same sweeper, same arithmetic, bit-identical responses (tested).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::Result;

use super::front::BatchFront;
use super::Model;

/// `S` independent micro-batching fronts plus the dispatch policy.
pub struct ShardedFront {
    shards: Vec<Arc<BatchFront>>,
    /// Rotating offset for the least-loaded predict deal's tie-break.
    rr: AtomicUsize,
}

impl ShardedFront {
    /// Spawn `shards` sweepers (≥ 1; clamped) with immediate drain.
    pub fn start(model: Arc<Model>, shards: usize) -> Arc<Self> {
        Self::start_with_holdoff(model, shards, 0)
    }

    /// Spawn `shards` sweepers, each with the given hold-off window (µs).
    pub fn start_with_holdoff(
        model: Arc<Model>,
        shards: usize,
        holdoff_us: u64,
    ) -> Arc<Self> {
        Self::start_configured(model, shards, holdoff_us, usize::MAX)
    }

    /// [`Self::start_with_holdoff`] with a per-shard trainer memory
    /// budget in bytes (`usize::MAX` = unlimited). Each shard's hub
    /// enforces the budget independently — lanes never migrate between
    /// shards, so a per-shard cap is a per-connection-population cap.
    pub fn start_configured(
        model: Arc<Model>,
        shards: usize,
        holdoff_us: u64,
        trainer_budget: usize,
    ) -> Arc<Self> {
        let shards = shards.max(1);
        let fronts = (0..shards)
            .map(|i| {
                BatchFront::start_configured(
                    Arc::clone(&model),
                    holdoff_us,
                    format!("lr-shard-{i}-sweeper"),
                    trainer_budget,
                )
            })
            .collect();
        Arc::new(Self {
            shards: fronts,
            rr: AtomicUsize::new(0),
        })
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Direct access to shard `i` (streaming lanes live on a shard).
    pub fn shard(&self, i: usize) -> &Arc<BatchFront> {
        &self.shards[i]
    }

    /// The model every shard serves.
    pub fn model(&self) -> &Arc<Model> {
        self.shards[0].model()
    }

    /// Home shard for a connection key: a pure function of the key
    /// (SplitMix64, uniform across shards) — the same key maps to the
    /// same shard on every call, on every run, at the same shard count.
    /// The wire layer derives the key from the peer IP (not the
    /// ephemeral port), so a reconnecting client hashes back to its
    /// previous home shard; any caller-supplied persistent identity gets
    /// the same stability from this function.
    pub fn shard_for_key(&self, key: u64) -> usize {
        (crate::rng::splitmix64_mix(key) % self.shards.len() as u64) as usize
    }

    /// The home front for a connection key.
    pub fn home(&self, key: u64) -> &Arc<BatchFront> {
        &self.shards[self.shard_for_key(key)]
    }

    /// Least-loaded shard for a stateless job, rotating the scan start so
    /// ties spread round-robin instead of piling on shard 0.
    fn pick_shard(&self) -> &Arc<BatchFront> {
        let n = self.shards.len();
        if n == 1 {
            return &self.shards[0];
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_depth = self.shards[start].queue_depth();
        for off in 1..n {
            let i = (start + off) % n;
            let d = self.shards[i].queue_depth();
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        &self.shards[best]
    }

    /// Stateless prediction, dealt to the least-loaded shard. Falls back
    /// to a direct same-precision computation if that shard's sweeper is
    /// gone (inside [`BatchFront::predict`]).
    pub fn predict(&self, input: Vec<f64>) -> Vec<f64> {
        self.pick_shard().predict(input)
    }

    /// Fan-out form of [`Self::predict`]: enqueue on the least-loaded
    /// shard and return the reply channel without blocking (benches and
    /// batch submitters collect the receivers afterwards).
    pub fn predict_async(
        &self,
        input: Vec<f64>,
    ) -> Option<mpsc::Receiver<super::front::Reply>> {
        self.pick_shard().predict_async(input)
    }

    /// Least-loaded-deal predict with an arbitrary reply sink — the
    /// event loop's form: it passes an `EventReply`, never blocks, and a
    /// refused job (sweeper gone) still resolves through the reply's
    /// `Dropped` completion, so the return value only reports whether
    /// the job was queued. The input `Arc` lets the caller keep its
    /// fallback copy without cloning the data.
    pub(crate) fn submit_predict_dealt(
        &self,
        input: Arc<Vec<f64>>,
        reply: super::front::ReplySender,
    ) -> bool {
        self.pick_shard().submit_predict(input, reply)
    }

    /// Streaming step(s) on a lane of shard `shard_idx`.
    pub fn stream(
        &self,
        shard_idx: usize,
        lane: usize,
        input: Vec<f64>,
    ) -> Result<Vec<f64>> {
        self.shards[shard_idx].stream(lane, input)
    }

    /// Per-shard queue depths (metrics; `info`).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.queue_depth()).collect()
    }

    /// Per-shard sweep counts (metrics; `info`).
    pub fn sweep_counts(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.sweep_count()).collect()
    }

    /// Total queued jobs across shards.
    pub fn queue_depth_total(&self) -> usize {
        self.queue_depths().iter().sum()
    }

    /// Total sweep rounds across shards.
    pub fn sweep_count_total(&self) -> u64 {
        self.sweep_counts().iter().sum()
    }

    /// Shut every shard down (idempotent). Each front drains its queued
    /// jobs before its sweeper exits, so no accepted job is dropped.
    pub fn shutdown(&self) {
        for s in &self.shards {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{make_model, make_model_f32};
    use super::*;
    use crate::tasks::mso::MsoTask;

    #[test]
    fn shard_hash_is_stable_and_covers_all_shards() {
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 4);
        let mut hit = [false; 4];
        for key in 0..256u64 {
            let s = front.shard_for_key(key);
            assert!(s < 4);
            hit[s] = true;
            // stability: the assignment is a pure function of the key —
            // a reconnect (same key, later in time) lands on the same
            // shard, as does a fresh facade over the same shard count
            assert_eq!(s, front.shard_for_key(key));
        }
        assert!(hit.iter().all(|h| *h), "256 keys must cover 4 shards");
        // a second sharded front (server restart) assigns identically
        let front2 = ShardedFront::start(Arc::clone(&model), 4);
        for key in 0..64u64 {
            assert_eq!(front.shard_for_key(key), front2.shard_for_key(key));
        }
        front.shutdown();
        front2.shutdown();
    }

    #[test]
    fn cross_shard_lanes_are_isolated() {
        // two streaming connections on DIFFERENT shards: interleaved
        // requests must each reproduce their solo trajectory exactly
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 2);
        let task = MsoTask::new(1);
        let lane0 = front.shard(0).acquire_lane().unwrap();
        let lane1 = front.shard(1).acquire_lane().unwrap();
        let in0 = &task.input[..40];
        let in1 = &task.input[150..185];
        let mut got0 = front.stream(0, lane0, in0[..13].to_vec()).unwrap();
        let mut got1 = front.stream(1, lane1, in1[..9].to_vec()).unwrap();
        got0.extend(front.stream(0, lane0, in0[13..].to_vec()).unwrap());
        got1.extend(front.stream(1, lane1, in1[9..].to_vec()).unwrap());
        for (got, input) in [(got0, in0), (got1, in1)] {
            let want = model.predict(input);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() == 0.0,
                    "cross-shard stream diverged: {a} vs {b}"
                );
            }
        }
        front.shard(0).release_lane(lane0);
        front.shard(1).release_lane(lane1);
        front.shutdown();
    }

    #[test]
    fn single_shard_bit_identical_to_batch_front() {
        // `--shards 1` must reproduce the PR-2 single-front server
        // bit-exactly, at both precisions, on both predicts and streams
        for model in [Arc::new(make_model()), Arc::new(make_model_f32())] {
            let sharded = ShardedFront::start(Arc::clone(&model), 1);
            let plain = BatchFront::start(Arc::clone(&model));
            let task = MsoTask::new(2);
            for i in 0..4 {
                let input = task.input[i * 9..i * 9 + 28 + i].to_vec();
                let a = sharded.predict(input.clone());
                let b = plain.predict(input);
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert!(
                        (x - y).abs() == 0.0,
                        "shards=1 predict != BatchFront: {x} vs {y}"
                    );
                }
            }
            // streaming: same lane, same chunks, same bits
            let ls = sharded.shard(0).acquire_lane().unwrap();
            let lp = plain.acquire_lane().unwrap();
            let input = &task.input[..44];
            let mut got_s = sharded.stream(0, ls, input[..20].to_vec()).unwrap();
            got_s.extend(sharded.stream(0, ls, input[20..].to_vec()).unwrap());
            let mut got_p = plain.stream(lp, input[..20].to_vec()).unwrap();
            got_p.extend(plain.stream(lp, input[20..].to_vec()).unwrap());
            assert_eq!(got_s.len(), got_p.len());
            for (x, y) in got_s.iter().zip(&got_p) {
                assert!(
                    (x - y).abs() == 0.0,
                    "shards=1 stream != BatchFront: {x} vs {y}"
                );
            }
            sharded.shutdown();
            plain.shutdown();
        }
    }

    #[test]
    fn shutdown_drains_all_shard_queues() {
        // jobs accepted before shutdown must all be answered — shutdown
        // wakes every sweeper, which drains its queue before exiting
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 3);
        let task = MsoTask::new(2);
        let inputs: Vec<Vec<f64>> = (0..12)
            .map(|i| task.input[i * 7..i * 7 + 20 + i].to_vec())
            .collect();
        let replies: Vec<_> = inputs
            .iter()
            .map(|input| {
                front
                    .predict_async(input.clone())
                    .expect("front accepts before shutdown")
            })
            .collect();
        front.shutdown();
        for (input, rx) in inputs.iter().zip(replies) {
            let got = match rx.recv().expect("queued job answered during drain") {
                super::super::front::Reply::Vals(v) => v,
                other => panic!("expected values, got {other:?}"),
            };
            let want = model.predict(input);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() == 0.0);
            }
        }
        assert_eq!(front.queue_depth_total(), 0, "queues drained");
        front.shutdown(); // idempotent
    }

    #[test]
    fn least_loaded_deal_spreads_a_burst() {
        // with every queue empty the rotating tie-break spreads
        // consecutive predicts across shards — observable as sweeps on
        // more than one shard after a burst
        let model = Arc::new(make_model());
        let front = ShardedFront::start(Arc::clone(&model), 2);
        let task = MsoTask::new(1);
        for i in 0..8 {
            let input = task.input[i * 5..i * 5 + 15].to_vec();
            let got = front.predict(input.clone());
            let want = model.predict(&input);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() == 0.0);
            }
        }
        let sweeps = front.sweep_counts();
        assert!(
            sweeps.iter().filter(|&&s| s > 0).count() >= 2,
            "8 sequential predicts on idle shards must touch both: {sweeps:?}"
        );
        front.shutdown();
    }
}
