//! Pooled stateless predict engines, keyed by `(model, chunk width)`.
//!
//! Every coalesced predict chunk used to construct a fresh precision-
//! matched [`super::front::Hub`] — a clone of the `(Λ, [W_in]_Q)`
//! parameter set, a parameter downcast (at f32), and three plane
//! allocations, paid per chunk on the hot path. Chunk sizes repeat
//! heavily in steady state (bounded by `MAX_PREDICT_BATCH`, and under
//! load almost always exactly `MAX_PREDICT_BATCH` or the queue
//! remainder), so the sweeper keeps one engine per `(model, width)` it
//! has seen and re-issues it after a lane reset — `O(slots × B⁺)`
//! zeroing instead of construction.
//!
//! **Model keying is a correctness requirement, not a cache policy**:
//! a width-only key would hand tenant B's coalesced predicts an engine
//! carrying tenant A's `(Λ, [W_in]_Q)` planes the moment two models'
//! chunks share a width. The key's model half routes every chunk to an
//! engine built from ITS model's planes (regression-tested below).
//!
//! The pool is owned by the sweeper thread (one per shard): no locks,
//! no sharing. Statelessness is preserved by construction: an engine is
//! zeroed on checkout, so a pooled sweep is bit-identical to one on a
//! freshly built engine (tested in `front.rs` and implied by every
//! bit-identity test that routes predicts through the front).
//!
//! Width keys are **bucketed to the padded lane width**: `BatchEsn` pads
//! its lane count up to `Scalar::LANES` anyway (8 at f64, 16 at f32), so
//! an engine built for `k` lanes and one built for `⌈k/LANES⌉·LANES`
//! lanes have byte-identical planes and do byte-identical work — and
//! lane results are independent of batch size and position (a tested
//! engine property), so serving a k-request chunk from the bucket-width
//! engine is bit-identical to a k-width engine. One engine per
//! `(model, bucket)` (≤ 4 buckets at f64, ≤ 2 at f32 with the
//! 32-predict cap) instead of one per chunk size.

use std::collections::HashMap;
use std::sync::Arc;

use crate::num::Scalar;

use super::front::Hub;
use super::registry::{ModelId, ModelRegistry, BASE_MODEL};
use super::{Model, Precision};

/// Per-sweeper cache of stateless predict engines, keyed by
/// `(model, padded lane-width bucket)`.
pub(crate) struct EnginePool {
    base: Arc<Model>,
    registry: Option<Arc<ModelRegistry>>,
    /// Per-model `Arc<Model>` resolved from the registry once, so
    /// repeated chunks for a warm model skip the registry lock.
    models: HashMap<ModelId, Arc<Model>>,
    engines: HashMap<(ModelId, usize), Hub>,
    built: u64,
}

impl EnginePool {
    pub(crate) fn new(
        base: Arc<Model>,
        registry: Option<Arc<ModelRegistry>>,
    ) -> Self {
        Self {
            base,
            registry,
            models: HashMap::new(),
            engines: HashMap::new(),
            built: 0,
        }
    }

    /// `lanes` rounded up to the model precision's padded lane width —
    /// the engine size `BatchEsn` would pad to internally anyway.
    fn bucket(precision: Precision, lanes: usize) -> usize {
        let w = match precision {
            Precision::F64 => <f64 as Scalar>::LANES,
            Precision::F32 => <f32 as Scalar>::LANES,
        };
        lanes.div_ceil(w) * w
    }

    /// The model behind an id: the base model for [`BASE_MODEL`], else
    /// the pool's cached resolution of the registry entry. `None` =
    /// unknown model (never minted, or deleted since submission).
    fn model_for(&mut self, model: ModelId) -> Option<Arc<Model>> {
        if model == BASE_MODEL {
            return Some(Arc::clone(&self.base));
        }
        if let Some(m) = self.models.get(&model) {
            return Some(Arc::clone(m));
        }
        let m = self.registry.as_ref()?.get(model)?;
        self.models.insert(model, Arc::clone(&m));
        Some(m)
    }

    /// Check out a pooled engine for `model` with at least `lanes` lanes
    /// (exactly the bucket width), building it from that model's planes
    /// on first use. The engine comes back zeroed, so callers see
    /// fresh-construction semantics either way; lanes beyond the
    /// caller's chunk stay zero and unobservable. `None` when the model
    /// is not (or no longer) in the registry — the caller answers the
    /// typed `unknown_model`.
    pub(crate) fn get(
        &mut self,
        model: ModelId,
        lanes: usize,
    ) -> Option<&mut Hub> {
        use std::collections::hash_map::Entry;
        let m = self.model_for(model)?;
        let bucket = Self::bucket(m.precision, lanes);
        let hub = match self.engines.entry((model, bucket)) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => {
                self.built += 1;
                // pooled predict engines never train, so no budget
                v.insert(Hub::new(&m, bucket, usize::MAX))
            }
        };
        hub.reset();
        Some(hub)
    }

    /// Drop cached engines (and model resolutions) for models deleted
    /// from the registry — engines are stateless, so dropping one costs
    /// only a rebuild if the id comes back. No-op with no tenant
    /// entries: the zero-tenant path never takes the registry lock.
    pub(crate) fn prune(&mut self) {
        if self.models.is_empty() {
            return;
        }
        let Some(reg) = self.registry.as_ref() else {
            return;
        };
        let live = reg.ids();
        self.models.retain(|id, _| live.binary_search(id).is_ok());
        self.engines.retain(|(id, _), _| {
            *id == BASE_MODEL || live.binary_search(id).is_ok()
        });
    }

    /// Distinct engines constructed so far (metrics: flat once warm).
    pub(crate) fn built(&self) -> u64 {
        self.built
    }
}

#[cfg(test)]
mod tests {
    use super::super::registry::ModelRecipe;
    use super::super::testutil::make_model;
    use super::*;

    #[test]
    fn pool_builds_once_per_bucket_and_resets_state() {
        // f64 model → bucket width 8: chunk sizes 1..=8 share one engine
        let model = Arc::new(make_model());
        let mut pool = EnginePool::new(Arc::clone(&model), None);
        let input: Vec<f64> = (0..20).map(|t| (t as f64 * 0.1).sin()).collect();

        let reqs: [(usize, &[f64]); 2] =
            [(0, input.as_slice()), (1, input.as_slice())];
        let first = pool
            .get(BASE_MODEL, 2)
            .unwrap()
            .sweep_streams(&reqs)
            .pop()
            .unwrap();
        assert_eq!(pool.built(), 1);
        // same bucket → reused engine, zeroed on checkout: identical
        let again = pool
            .get(BASE_MODEL, 2)
            .unwrap()
            .sweep_streams(&reqs)
            .pop()
            .unwrap();
        assert_eq!(pool.built(), 1, "chunk size 2 must not rebuild");
        assert_eq!(first, again, "pooled engine must be stateless");
        // bit-identity across bucket sharing: the engine is batch-size
        // independent per lane, so the bucket-width sweep equals the
        // sequential model path exactly
        let direct = model.predict(&input);
        assert_eq!(first, direct, "bucketed sweep must match Model::predict");
        // chunk size 5 lands in the same 8-wide bucket: no rebuild
        let _ = pool.get(BASE_MODEL, 5);
        assert_eq!(pool.built(), 1, "sizes 1..=8 share the f64 bucket");
        // size 9 crosses into the next bucket
        let _ = pool.get(BASE_MODEL, 9);
        assert_eq!(pool.built(), 2);
        // and the original bucket is still cached
        let _ = pool.get(BASE_MODEL, 8);
        assert_eq!(pool.built(), 2);
    }

    #[test]
    fn two_tenants_never_share_an_engine() {
        // the model-blindness regression: same chunk width, different
        // models — a width-only key would serve tenant B from tenant A's
        // planes. Two single-tenant pools are the ground truth.
        let base = Arc::new(make_model());
        let registry = Arc::new(ModelRegistry::new(Arc::clone(&base), 8));
        let ra = ModelRecipe::new(11, 40, 0.8, "uniform").unwrap();
        let rb = ModelRecipe::new(22, 40, 0.8, "uniform").unwrap();
        let (a, _) = registry.create(&ra).unwrap();
        let (b, _) = registry.create(&rb).unwrap();
        assert_ne!(a, b);

        let input: Vec<f64> = (0..30).map(|t| (t as f64 * 0.07).sin()).collect();
        let reqs: [(usize, &[f64]); 1] = [(0, input.as_slice())];

        let mut pool =
            EnginePool::new(Arc::clone(&base), Some(Arc::clone(&registry)));
        // same width bucket, interleaved checkouts
        let out_a = pool.get(a, 1).unwrap().sweep_streams(&reqs).pop().unwrap();
        let out_b = pool.get(b, 1).unwrap().sweep_streams(&reqs).pop().unwrap();
        let out_a2 = pool.get(a, 1).unwrap().sweep_streams(&reqs).pop().unwrap();
        assert_eq!(
            pool.built(),
            2,
            "one engine per (model, bucket): A and B must not share"
        );
        assert_eq!(out_a, out_a2, "A's engine must be stable across B's use");

        // ground truth: each tenant alone in a fresh pool
        let mut solo =
            EnginePool::new(Arc::clone(&base), Some(Arc::clone(&registry)));
        let solo_a = solo.get(a, 1).unwrap().sweep_streams(&reqs).pop().unwrap();
        let mut solo =
            EnginePool::new(Arc::clone(&base), Some(Arc::clone(&registry)));
        let solo_b = solo.get(b, 1).unwrap().sweep_streams(&reqs).pop().unwrap();
        assert_eq!(out_a, solo_a, "tenant A must see its own planes");
        assert_eq!(out_b, solo_b, "tenant B must see its own planes");
        assert_ne!(solo_a, solo_b, "distinct seeds ⇒ distinct predictions");

        // unknown model → None (typed refusal upstream), nothing built
        let built = pool.built();
        assert!(pool.get(12345, 1).is_none());
        assert_eq!(pool.built(), built);

        // delete + prune drops B's engine but keeps A's and the base's
        registry.delete(b).unwrap();
        let _ = pool.get(BASE_MODEL, 1);
        let n_before = pool.engines.len();
        pool.prune();
        assert_eq!(pool.engines.len(), n_before - 1);
        assert!(pool.get(b, 1).is_none(), "deleted model must stay gone");
        assert!(pool.get(a, 1).is_some());
    }
}
