//! Pooled stateless predict engines, keyed by chunk size.
//!
//! Every coalesced predict chunk used to construct a fresh precision-
//! matched [`super::front::Hub`] — a clone of the `(Λ, [W_in]_Q)`
//! parameter set, a parameter downcast (at f32), and three plane
//! allocations, paid per chunk on the hot path. Chunk sizes repeat
//! heavily in steady state (bounded by `MAX_PREDICT_BATCH`, and under
//! load almost always exactly `MAX_PREDICT_BATCH` or the queue
//! remainder), so the sweeper keeps one engine per chunk size it has
//! seen and re-issues it after a lane reset — `O(slots × B⁺)` zeroing
//! instead of construction.
//!
//! The pool is owned by the sweeper thread (one per shard): no locks,
//! no sharing. Statelessness is preserved by construction: an engine is
//! zeroed on checkout, so a pooled sweep is bit-identical to one on a
//! freshly built engine (tested in `front.rs` and implied by every
//! bit-identity test that routes predicts through the front).
//!
//! Keys are **bucketed to the padded lane width**: `BatchEsn` pads its
//! lane count up to `Scalar::LANES` anyway (8 at f64, 16 at f32), so an
//! engine built for `k` lanes and one built for `⌈k/LANES⌉·LANES` lanes
//! have byte-identical planes and do byte-identical work — and lane
//! results are independent of batch size and position (a tested engine
//! property), so serving a k-request chunk from the bucket-width engine
//! is bit-identical to a k-width engine. One engine per bucket (4 at
//! f64, 2 at f32 with the 32-predict cap) instead of one per chunk size.

use std::collections::HashMap;
use std::sync::Arc;

use crate::num::Scalar;

use super::front::Hub;
use super::{Model, Precision};

/// Per-sweeper cache of stateless predict engines, keyed by the padded
/// lane-width bucket.
pub(crate) struct EnginePool {
    model: Arc<Model>,
    engines: HashMap<usize, Hub>,
    built: u64,
}

impl EnginePool {
    pub(crate) fn new(model: Arc<Model>) -> Self {
        Self {
            model,
            engines: HashMap::new(),
            built: 0,
        }
    }

    /// `lanes` rounded up to the model precision's padded lane width —
    /// the engine size `BatchEsn` would pad to internally anyway.
    fn bucket(&self, lanes: usize) -> usize {
        let w = match self.model.precision {
            Precision::F64 => <f64 as Scalar>::LANES,
            Precision::F32 => <f32 as Scalar>::LANES,
        };
        lanes.div_ceil(w) * w
    }

    /// Check out a pooled engine with at least `lanes` lanes (exactly the
    /// bucket width), building it on first use. The engine comes back
    /// zeroed, so callers see fresh-construction semantics either way;
    /// lanes beyond the caller's chunk stay zero and unobservable.
    pub(crate) fn get(&mut self, lanes: usize) -> &mut Hub {
        use std::collections::hash_map::Entry;
        let bucket = self.bucket(lanes);
        let hub = match self.engines.entry(bucket) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => {
                self.built += 1;
                // pooled predict engines never train, so no budget
                v.insert(Hub::new(&self.model, bucket, usize::MAX))
            }
        };
        hub.reset();
        hub
    }

    /// Distinct engines constructed so far (metrics: flat once warm).
    pub(crate) fn built(&self) -> u64 {
        self.built
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::make_model;
    use super::*;

    #[test]
    fn pool_builds_once_per_bucket_and_resets_state() {
        // f64 model → bucket width 8: chunk sizes 1..=8 share one engine
        let model = Arc::new(make_model());
        let mut pool = EnginePool::new(Arc::clone(&model));
        let input: Vec<f64> = (0..20).map(|t| (t as f64 * 0.1).sin()).collect();

        let reqs: [(usize, &[f64]); 2] =
            [(0, input.as_slice()), (1, input.as_slice())];
        let first = pool.get(2).sweep_streams(&reqs).pop().unwrap();
        assert_eq!(pool.built(), 1);
        // same bucket → reused engine, zeroed on checkout: identical
        let again = pool.get(2).sweep_streams(&reqs).pop().unwrap();
        assert_eq!(pool.built(), 1, "chunk size 2 must not rebuild");
        assert_eq!(first, again, "pooled engine must be stateless");
        // bit-identity across bucket sharing: the engine is batch-size
        // independent per lane, so the bucket-width sweep equals the
        // sequential model path exactly
        let direct = model.predict(&input);
        assert_eq!(first, direct, "bucketed sweep must match Model::predict");
        // chunk size 5 lands in the same 8-wide bucket: no rebuild
        let _ = pool.get(5);
        assert_eq!(pool.built(), 1, "sizes 1..=8 share the f64 bucket");
        // size 9 crosses into the next bucket
        let _ = pool.get(9);
        assert_eq!(pool.built(), 2);
        // and the original bucket is still cached
        let _ = pool.get(8);
        assert_eq!(pool.built(), 2);
    }
}
