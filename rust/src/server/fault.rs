//! Deterministic fault injection for the chaos suite.
//!
//! The hooks in this module are compiled to no-ops unless the
//! `fault-inject` cargo feature is enabled (unit tests inside the crate
//! get them too, via `cfg(test)`), so the production hot path pays at
//! most one relaxed atomic load per stateful job and nothing at all on
//! the socket path. Armed faults fire exactly once (or persist, for the
//! write-shaping hook) and are fully described by process-global state:
//! tests serialize on a lock, arm a fault, drive the server, observe the
//! typed degradation, and `disarm()`.
//!
//! | hook | failure it injects |
//! |------|--------------------|
//! | [`arm_sweeper_panic`] | sweep-loop panic after N stateful jobs — exercises catch_unwind containment (lane quarantine + in-place restart) |
//! | [`arm_sweeper_kill`] | unrecoverable sweeper death after N stateful jobs (a [`SweeperKill`] payload escalates past the containment) |
//! | [`set_short_writes`] | short socket writes in the poll loop: at most `chunk` bytes per `write(2)`, optionally sleeping first — a deterministically slow reader |
//! | [`force_trainer_budget`] | overrides the hub trainer budget to a chosen byte count — allocation exhaustion without gigabytes of traffic |
//! | [`force_admit_depth`] | overrides the per-shard queue admission depth — typed `overloaded` shedding without a real request storm |
//! | [`arm_poll_thread_kill`] | death of ONE poll thread of the multi-thread event loop — its connections answer typed `unavailable` and close; sibling poll threads and every sweeper keep serving |

#[cfg(any(test, feature = "fault-inject"))]
mod armed {
    use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
    use std::sync::Mutex;
    use std::time::Duration;

    /// Panic payload that must NOT be contained: the sweep loop's
    /// catch_unwind rethrows it so the injected fault reproduces the
    /// legacy whole-front death (the failure mode the containment path
    /// is measured against).
    pub struct SweeperKill;

    /// Remaining stateful jobs before the armed sweeper fault fires;
    /// <= 0 means disarmed.
    static SWEEP_FUSE: AtomicI64 = AtomicI64::new(0);
    /// 1 = the armed fault is a hard kill ([`SweeperKill`] payload),
    /// 0 = a containable panic.
    static SWEEP_KILL: AtomicUsize = AtomicUsize::new(0);
    /// Max bytes per socket write; 0 = unshaped.
    static WRITE_CHUNK: AtomicUsize = AtomicUsize::new(0);
    /// Microseconds to sleep before each shaped write.
    static WRITE_DELAY_US: AtomicU64 = AtomicU64::new(0);
    /// Trainer-budget override in bytes; u64::MAX = no override.
    static BUDGET: AtomicU64 = AtomicU64::new(u64::MAX);
    /// Queue-admission depth override; u64::MAX = no override.
    static ADMIT_DEPTH: AtomicU64 = AtomicU64::new(u64::MAX);
    /// Poll-thread index armed to die (+1, so 0 = disarmed).
    static POLL_KILL: AtomicU64 = AtomicU64::new(0);
    /// When set, an armed sweeper fuse only ticks down on the named
    /// sweeper thread. Unit tests share one process and run in
    /// parallel, so an unscoped fuse could fire on an UNRELATED test's
    /// sweeper; scoping by thread name pins the blast radius.
    static TARGET_THREAD: Mutex<Option<String>> = Mutex::new(None);

    /// Restrict armed sweeper faults to the sweeper thread with this
    /// exact name (see `BatchFront::start_configured`). Cleared by
    /// [`disarm`].
    pub fn target_sweeper_thread(name: &str) {
        *TARGET_THREAD.lock().unwrap() = Some(name.to_string());
    }

    /// Arm a containable sweep panic that fires on the `after_jobs`-th
    /// stateful job (1 = the very next one) counted across all sweepers.
    pub fn arm_sweeper_panic(after_jobs: u64) {
        SWEEP_KILL.store(0, Ordering::SeqCst);
        SWEEP_FUSE.store(after_jobs as i64, Ordering::SeqCst);
    }

    /// Arm an unrecoverable sweeper kill (escalates past containment).
    pub fn arm_sweeper_kill(after_jobs: u64) {
        SWEEP_KILL.store(1, Ordering::SeqCst);
        SWEEP_FUSE.store(after_jobs as i64, Ordering::SeqCst);
    }

    /// Shape every subsequent poll-loop socket write: at most `chunk`
    /// bytes per call, sleeping `delay` first (a deterministic slow
    /// reader / EAGAIN generator). `chunk = 0` un-shapes.
    pub fn set_short_writes(chunk: usize, delay: Duration) {
        WRITE_DELAY_US.store(delay.as_micros() as u64, Ordering::SeqCst);
        WRITE_CHUNK.store(chunk, Ordering::SeqCst);
    }

    /// Override every hub's trainer budget (bytes) until [`disarm`].
    pub fn force_trainer_budget(bytes: usize) {
        BUDGET.store(bytes as u64, Ordering::SeqCst);
    }

    /// Override every shard's queue-admission depth until [`disarm`]:
    /// `0` sheds every queued job with the typed `overloaded` error —
    /// a deterministic overload without a real request storm.
    pub fn force_admit_depth(depth: usize) {
        ADMIT_DEPTH.store(depth as u64, Ordering::SeqCst);
    }

    /// Arm the death of poll thread `idx` (of the event-loop transport):
    /// at its next readiness round it answers every owned connection
    /// with the typed `unavailable` error and exits, leaving its sibling
    /// poll threads (and every sweeper) serving. One-shot: consumed by
    /// the first matching thread.
    pub fn arm_poll_thread_kill(idx: usize) {
        POLL_KILL.store(idx as u64 + 1, Ordering::SeqCst);
    }

    /// Clear every armed fault.
    pub fn disarm() {
        SWEEP_FUSE.store(0, Ordering::SeqCst);
        SWEEP_KILL.store(0, Ordering::SeqCst);
        WRITE_CHUNK.store(0, Ordering::SeqCst);
        WRITE_DELAY_US.store(0, Ordering::SeqCst);
        BUDGET.store(u64::MAX, Ordering::SeqCst);
        ADMIT_DEPTH.store(u64::MAX, Ordering::SeqCst);
        POLL_KILL.store(0, Ordering::SeqCst);
        *TARGET_THREAD.lock().unwrap() = None;
    }

    /// Called by the sweeper once per stateful job. Panics when an armed
    /// fuse reaches zero — inside the sweep loop's catch_unwind.
    pub(crate) fn sweeper_job_tick() {
        if SWEEP_FUSE.load(Ordering::SeqCst) <= 0 {
            return; // nothing armed: one atomic load on the test path
        }
        if let Some(target) = TARGET_THREAD.lock().unwrap().as_deref() {
            if std::thread::current().name() != Some(target) {
                return;
            }
        }
        let fired = SWEEP_FUSE
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                if v > 0 {
                    Some(v - 1)
                } else {
                    None
                }
            })
            .map(|prev| prev == 1)
            .unwrap_or(false);
        if fired {
            if SWEEP_KILL.load(Ordering::SeqCst) == 1 {
                std::panic::panic_any(SweeperKill);
            }
            panic!("fault-inject: armed sweeper panic fired");
        }
    }

    /// Current write shaping, if armed: `(max_bytes, pre-write delay)`.
    pub(crate) fn short_write_chunk() -> Option<(usize, Duration)> {
        match WRITE_CHUNK.load(Ordering::Relaxed) {
            0 => None,
            c => Some((
                c,
                Duration::from_micros(WRITE_DELAY_US.load(Ordering::Relaxed)),
            )),
        }
    }

    /// Current trainer-budget override in bytes, if armed.
    pub(crate) fn budget_override() -> Option<usize> {
        match BUDGET.load(Ordering::Relaxed) {
            u64::MAX => None,
            b => Some(b as usize),
        }
    }

    /// Consume an armed kill for poll thread `idx`, if one is armed.
    /// Compare-and-swap so exactly ONE loop round observes it.
    pub(crate) fn poll_thread_kill(idx: usize) -> bool {
        let armed = idx as u64 + 1;
        POLL_KILL
            .compare_exchange(armed, 0, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
    }

    /// Current queue-admission depth override for the front whose
    /// sweeper thread has this name, if armed. Scoped exactly like the
    /// sweeper fuse: with a [`target_sweeper_thread`] set, only that
    /// front sheds — parallel unit tests' fronts are untouched.
    pub(crate) fn admit_depth_override_for(sweeper: &str) -> Option<usize> {
        let depth = match ADMIT_DEPTH.load(Ordering::Relaxed) {
            u64::MAX => return None,
            d => d as usize,
        };
        if let Some(target) = TARGET_THREAD.lock().unwrap().as_deref() {
            if sweeper != target {
                return None;
            }
        }
        Some(depth)
    }
}

#[cfg(any(test, feature = "fault-inject"))]
pub use armed::{
    arm_poll_thread_kill, arm_sweeper_kill, arm_sweeper_panic, disarm,
    force_admit_depth, force_trainer_budget, set_short_writes,
    target_sweeper_thread, SweeperKill,
};
#[cfg(any(test, feature = "fault-inject"))]
pub(crate) use armed::{
    admit_depth_override_for, budget_override, poll_thread_kill,
    short_write_chunk, sweeper_job_tick,
};

/// No-op twin (nothing armed, nothing armable) — the production build.
#[cfg(not(any(test, feature = "fault-inject")))]
mod disarmed {
    #[inline(always)]
    pub(crate) fn sweeper_job_tick() {}

    #[inline(always)]
    pub(crate) fn short_write_chunk() -> Option<(usize, std::time::Duration)> {
        None
    }

    #[inline(always)]
    pub(crate) fn budget_override() -> Option<usize> {
        None
    }

    #[inline(always)]
    pub(crate) fn admit_depth_override_for(_sweeper: &str) -> Option<usize> {
        None
    }

    #[inline(always)]
    pub(crate) fn poll_thread_kill(_idx: usize) -> bool {
        false
    }
}
#[cfg(not(any(test, feature = "fault-inject")))]
pub(crate) use disarmed::{
    admit_depth_override_for, budget_override, poll_thread_kill,
    short_write_chunk, sweeper_job_tick,
};
