//! Seeded property-test harness (proptest is not in the offline registry).
//!
//! [`check`] runs a property over `cases` deterministic random seeds; on
//! failure it reports the offending seed so the case can be replayed with
//! `check_one`. Used by the invariant tests across linalg / spectral /
//! reservoir modules.

use crate::rng::Pcg64;

/// Run `prop` for `cases` seeded generators; panic with the failing seed
/// and message on the first violation.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Pcg64) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Pcg64::new(seed, 0x9e37);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single seed (debugging helper; also used by tests to pin
/// regressions).
pub fn check_one(name: &str, seed: u64, mut prop: impl FnMut(&mut Pcg64) -> Result<(), String>) {
    let mut rng = Pcg64::new(seed, 0x9e37);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed at seed {seed}: {msg}");
    }
}

/// Assert two slices are elementwise close (absolute + relative blend).
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, ctx: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{ctx}: length {} vs {}", a.len(), b.len()));
    }
    let scale = b.iter().fold(1.0f64, |m, x| m.max(x.abs()));
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * scale {
            return Err(format!(
                "{ctx}: index {i}: {x} vs {y} (scale {scale}, tol {tol})"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Distributions;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", 10, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn failing_property_reports_seed() {
        check("fail-on-3", 10, |rng| {
            let x = rng.uniform(0.0, 1.0);
            if x < 0.9 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    fn assert_close_behaviour() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, "t").is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, "t").is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, "t").is_err());
    }
}
