//! Sparse CSR matrix — the standard reservoir baseline uses connectivity
//! `c_r` ≪ 1, and the paper's complexity table (§2.5) credits the dense
//! baseline with sparse matvecs (`O(c_r·N²)`); this module makes that
//! baseline honest.

use crate::linalg::Mat;
use crate::rng::{Distributions, Pcg64};

/// Compressed Sparse Row matrix (f64).
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// row i occupies indices `indptr[i]..indptr[i+1]`
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl Csr {
    /// Build from a dense matrix, keeping entries with |x| > 0.
    pub fn from_dense(a: &Mat) -> Self {
        let mut indptr = Vec::with_capacity(a.rows() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                let v = a[(i, j)];
                if v != 0.0 {
                    indices.push(j);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Self {
            rows: a.rows(),
            cols: a.cols(),
            indptr,
            indices,
            values,
        }
    }

    /// Random sparse matrix: each entry present with probability
    /// `connectivity`, values i.i.d. standard normal (the paper's reservoir
    /// generation recipe, §2.5).
    pub fn random(rows: usize, cols: usize, connectivity: f64, rng: &mut Pcg64) -> Self {
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for _ in 0..rows {
            for j in 0..cols {
                if rng.bernoulli(connectivity) {
                    indices.push(j);
                    values.push(rng.normal());
                }
            }
            indptr.push(indices.len());
        }
        Self {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Effective connectivity (`nnz / (rows·cols)`).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Row-vector × matrix: `y = x · self` — the reservoir-step direction
    /// (`r(t−1)·W`). O(nnz).
    pub fn vecmat(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            for k in lo..hi {
                y[self.indices[k]] += xi * self.values[k];
            }
        }
    }

    /// Matrix × column-vector: `y = self · x`. O(nnz).
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            let mut s = 0.0;
            for k in lo..hi {
                s += self.values[k] * x[self.indices[k]];
            }
            y[i] = s;
        }
    }

    /// Densify (tests, eigendecomposition of sparse reservoirs).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                m[(i, self.indices[k])] = self.values[k];
            }
        }
        m
    }

    /// Scale all stored values in place (spectral-radius normalization).
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.values {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let a = Csr::random(10, 8, 0.3, &mut rng);
        let d = a.to_dense();
        let back = Csr::from_dense(&d);
        assert_eq!(a.nnz(), back.nnz());
        assert!(d.max_abs_diff(&back.to_dense()) < 1e-15);
    }

    #[test]
    fn vecmat_matches_dense() {
        let mut rng = Pcg64::seeded(2);
        let a = Csr::random(12, 9, 0.4, &mut rng);
        let d = a.to_dense();
        let x = rng.normal_vec(12);
        let mut ys = vec![0.0; 9];
        let mut yd = vec![0.0; 9];
        a.vecmat(&x, &mut ys);
        d.vecmat(&x, &mut yd);
        for j in 0..9 {
            assert!((ys[j] - yd[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::seeded(3);
        let a = Csr::random(7, 11, 0.5, &mut rng);
        let d = a.to_dense();
        let x = rng.normal_vec(11);
        let mut ys = vec![0.0; 7];
        let mut yd = vec![0.0; 7];
        a.matvec(&x, &mut ys);
        d.matvec(&x, &mut yd);
        for j in 0..7 {
            assert!((ys[j] - yd[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn density_tracks_connectivity() {
        let mut rng = Pcg64::seeded(4);
        let a = Csr::random(200, 200, 0.1, &mut rng);
        assert!((a.density() - 0.1).abs() < 0.01, "{}", a.density());
    }

    #[test]
    fn empty_matrix_ok() {
        let mut rng = Pcg64::seeded(5);
        let a = Csr::random(5, 5, 0.0, &mut rng);
        assert_eq!(a.nnz(), 0);
        let mut y = vec![1.0; 5];
        a.vecmat(&[1.0; 5], &mut y);
        assert_eq!(y, vec![0.0; 5]);
    }
}
