//! `repro` — the leader binary: regenerates every table/figure of the
//! paper, runs the end-to-end pipeline, and serves trained models.
//!
//! ```text
//! repro table2 [--tasks 1,2,…] [--seeds N] [--n N] [--quick]
//! repro fig2   [--sizes 100,300,…] [--quick]
//! repro fig3   [--n 500]
//! repro fig4   [--k 5]
//! repro fig5   [--k 8] [--n 100]
//! repro fig6   [--sizes 100,300] [--seeds 3] [--full]
//! repro fig7   [--sizes 100,300] [--seeds 3] [--full]
//! repro ablation-noise | ablation-eigvec | ablation-gamma
//! repro e2e    [--k 5] [--n 100]
//! repro serve  [--addr 127.0.0.1:7878] [--k 5] [--n 100] [--f32]
//!              [--holdoff-us 0] [--shards 0]   # 0 = one per core
//!              [--idle-timeout-s 0]  # reap silent connections
//!                                    # (0 = never; event loop only)
//!              [--threaded]   # thread-per-connection A/B transport
//!                             # (default: epoll event loop on Linux)
//!              [--trainer-budget-mb M]  # cap per-shard trainer
//!                                       # memory (absent = unlimited)
//!              [--rebalance]  # migrate hot lanes between shards when
//!                             # sweep-occupancy skew crosses threshold
//!              [--standby a:p,b:p,…]        # stream per-lane checkpoint
//!              [--standby-interval-ms 200]  # deltas to warm replicas
//!              [--drain-checkpoint DIR] # on SIGTERM/shutdown_drain,
//!                                       # spill live lanes to DIR
//!              [--peers a:p,b:p,…]   # cluster mode: consistent-hash
//!                                    # the key space across the group
//!              [--advertise host:port]  # own address as peers spell it
//!              [--ping-interval-ms 50]  # gossip liveness cadence
//!              [--holdoff-auto]  # derive the coalescing window from
//!                                # arrival EWMA (cap = --holdoff-us)
//!              [--max-models 256]  # per-tenant model registry budget
//!                                  # (0 = base model only)
//!              [--pin-cores]  # pin each shard's sweeper thread to a
//!                             # core (round-robin sched_setaffinity)
//!              [--poll-threads P]  # shard connections across P epoll
//!                                  # threads (1 = classic single loop)
//! repro all    [--quick]       # every driver with small budgets
//! ```

use anyhow::Result;
use linear_reservoir::cli::Args;
use linear_reservoir::coordinator::{GridSpec, MethodKind};
use linear_reservoir::experiments::{
    ablation, fig2, fig3, fig4, fig5, fig6, fig7, results_dir, table2,
};
use linear_reservoir::util::Timer;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", HELP);
            std::process::exit(2);
        }
    };
    let t = Timer::start();
    let result = dispatch(&args);
    match result {
        Ok(()) => println!("\ndone in {:.1}s", t.elapsed_s()),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

const HELP: &str = "usage: repro <table2|fig2|fig3|fig4|fig5|fig6|fig7|\
ablation-noise|ablation-eigvec|ablation-gamma|e2e|serve|all|help> [--opts]";

fn parse_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|x| x.trim().parse::<usize>().map_err(Into::into))
        .collect()
}

fn dispatch(args: &Args) -> Result<()> {
    let out = results_dir();
    match args.subcommand.as_str() {
        "help" => {
            println!("{HELP}");
            Ok(())
        }
        "table2" => {
            let tasks = match args.get("tasks") {
                Some(s) => parse_list(s)?,
                None => (1..=12).collect(),
            };
            let seeds = args.get_u64("seeds", 10)?;
            let n = args.get_usize("n", 100)?;
            let spec = if args.flag("quick") {
                GridSpec::quick()
            } else {
                GridSpec::paper_table1()
            };
            let methods = MethodKind::table2_set();
            println!(
                "Table 2: tasks {tasks:?}, {seeds} seeds, grid size {}",
                spec.size()
            );
            let cells = table2::run(&tasks, &methods, seeds, spec, n, true)?;
            table2::emit(&cells, &methods, &out.join("table2.csv"))?;
            println!("\nwins per method:");
            for (label, count) in table2::wins(&cells, &methods) {
                println!("  {label:<18} {count}");
            }
            Ok(())
        }
        "fig2" => {
            let sizes = match args.get("sizes") {
                Some(s) => parse_list(s)?,
                None => vec![50, 100, 200, 400, 800, 1600],
            };
            let quick = args.flag("quick");
            let rows = fig2::run(&sizes, if quick { 1 } else { 3 }, quick)?;
            fig2::emit(&rows, &out.join("fig2.csv"))
        }
        "fig3" => {
            let n = args.get_usize("n", 500)?;
            let points = fig3::run(n, args.get_u64("seed", 0)?);
            fig3::emit(&points, &out.join("fig3.csv"))
        }
        "fig4" => {
            let k = args.get_usize("k", 5)?;
            let rows = fig4::run(k);
            fig4::emit(&rows, &out.join("fig4.csv"))
        }
        "fig5" => {
            let k = args.get_usize("k", 8)?;
            let n = args.get_usize("n", 100)?;
            let points = fig5::run(k, n, args.get_u64("seed", 0)?, 1e-8)?;
            fig5::emit(&points, k, &out.join("fig5.csv"))
        }
        "fig6" => {
            let sizes = match args.get("sizes") {
                Some(s) => parse_list(s)?,
                None if args.flag("full") => vec![100, 300, 600, 1000],
                None => vec![100, 300],
            };
            let seeds = args.get_u64("seeds", 3)?;
            let rows = fig6::run(&sizes, seeds, 1e-7, true)?;
            fig6::emit(&rows, &out.join("fig6.csv"))
        }
        "fig7" => {
            let sizes = match args.get("sizes") {
                Some(s) => parse_list(s)?,
                None if args.flag("full") => vec![100, 300, 600, 1000],
                None => vec![100, 300],
            };
            let seeds = args.get_u64("seeds", 3)?;
            let conns = fig7::connectivity_grid();
            let mut all = Vec::new();
            for n in sizes {
                let delay = match args.get("delay") {
                    Some(d) => d.parse()?,
                    None => {
                        let d = fig7::calibrate_delay(n, seeds.min(2), 1e-7)?;
                        println!("  N={n}: calibrated delay {d} (MC≈0.5 at conn=1)");
                        d
                    }
                };
                let rows = fig7::run(n, delay, &conns, seeds, 1e-7, true)?;
                all.extend(rows);
            }
            fig7::emit(&all, &out.join("fig7.csv"))
        }
        "ablation-noise" => {
            let k = args.get_usize("k", 5)?;
            let seeds = args.get_u64("seeds", 3)?;
            let spec = if args.flag("full") {
                GridSpec::paper_table1()
            } else {
                GridSpec::quick()
            };
            let rows = ablation::noise_sweep(
                k,
                &[0.0, 0.05, 0.1, 0.2, 0.4],
                seeds,
                spec,
                args.get_usize("n", 100)?,
            )?;
            ablation::emit_noise_sweep(&rows, &out.join("ablation_noise.csv"))
        }
        "ablation-eigvec" => {
            let scores = ablation::eigvec_role(
                args.get_usize("k", 5)?,
                args.get_usize("n", 100)?,
                args.get_u64("resamples", 8)?,
                1e-8,
            )?;
            let s = linear_reservoir::util::stats::Summary::of(&scores);
            println!(
                "eigenvector-role ablation: rmse mean={:.3e} min={:.3e} max={:.3e} \
                 (spread ×{:.1})",
                s.mean,
                s.min,
                s.max,
                s.max / s.min.max(1e-300)
            );
            Ok(())
        }
        "ablation-gamma" => {
            let (std_rmse, gamma_rmse) = ablation::gamma_readout(
                args.get_usize("k", 5)?,
                args.get_usize("n", 100)?,
                args.get_u64("seed", 0)?,
                1e-9,
            )?;
            println!(
                "Appendix-C γ readout: standard rmse={std_rmse:.3e}, γ rmse={gamma_rmse:.3e}"
            );
            Ok(())
        }
        "e2e" => run_e2e(
            args.get_usize("k", 5)?,
            args.get_usize("n", 100)?,
            args.get_u64("seed", 0)?,
            1e-8,
        ),
        "run" => {
            use linear_reservoir::coordinator::ExperimentSpec;
            let path = args
                .get("config")
                .ok_or_else(|| anyhow::anyhow!("run requires --config <file.json>"))?;
            let text = std::fs::read_to_string(path)?;
            let spec = ExperimentSpec::from_json_str(&text)?;
            let r = spec.execute()?;
            println!(
                "config {path}: test RMSE {:.3e}, NRMSE {:.3e} ({} train / {} test rows)",
                r.test_rmse, r.test_nrmse, r.train_rows, r.test_rows
            );
            Ok(())
        }
        "serve" => {
            use linear_reservoir::readout::{fit, Regularizer};
            use linear_reservoir::reservoir::{DiagonalEsn, EsnConfig};
            use linear_reservoir::rng::Pcg64;
            use linear_reservoir::server::{serve_on_opts, Model, Precision, ServeOpts};
            use linear_reservoir::spectral::golden::{golden_spectrum, GoldenParams};
            use linear_reservoir::tasks::mso::{slice_rows, MsoTask};
            use std::sync::Arc;

            let k = args.get_usize("k", 5)?;
            let n = args.get_usize("n", 100)?;
            let addr = args.get_str("addr", "127.0.0.1:7878");
            let config = EsnConfig::default().with_n(n).with_sr(0.9).with_seed(0);
            let mut rng = Pcg64::new(0, 70);
            let spec =
                golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.2 }, &mut rng);
            let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);
            let task = MsoTask::new(k);
            let splits = MsoTask::splits();
            let feats = esn.run(&task.input_mat());
            let x = slice_rows(&feats, splits.train.clone());
            let y = task.target_mat(splits.train.clone());
            let readout = fit(&x, &y, 1e-8, true, Regularizer::Identity)?;
            // --f32: serve from the f32 SoA lane engine (2× SIMD width;
            // wire format unchanged — see rust/tests/precision.rs)
            let precision = if args.flag("f32") {
                Precision::F32
            } else {
                Precision::F64
            };
            // --holdoff-us: opt-in sweeper coalescing window (0 = drain
            // immediately)
            let holdoff_us = args.get_u64("holdoff-us", 0)?;
            // --shards: sweepers (one hub + engine pool each); 0 = one
            // per available core; 1 = the single-front legacy behavior
            let shards = match args.get_usize("shards", 0)? {
                0 => None,
                s => Some(s),
            };
            // --threaded: thread-per-connection transport (the A/B twin
            // of the default epoll event loop; on non-Linux platforms
            // the threaded path is the only transport either way)
            let threaded = args.flag("threaded");
            // --idle-timeout-s: reap connections silent this long (0 =
            // never; only the event-loop transport has the timer wheel)
            let idle_s = args.get_u64("idle-timeout-s", 0)?;
            let idle_timeout =
                (idle_s > 0).then(|| std::time::Duration::from_secs(idle_s));
            // --trainer-budget-mb: cap trainer-accumulator memory per
            // shard; training past it answers the typed
            // `trainer_budget` error. Absent = unlimited (`--trainer-
            // budget-mb 0` refuses all training, which is also valid).
            let trainer_budget = args
                .get_opt_u64("trainer-budget-mb")?
                .map(|mb| (mb as usize) << 20);
            // --rebalance: opt-in background lane migration off the
            // hottest shard when the sweep-occupancy EWMA skew crosses
            // the threshold (see DESIGN.md §11)
            let rebalance = args.flag("rebalance");
            // --standby: warm-replica address; a pusher thread streams
            // dirty-lane checkpoint deltas there over the normal wire
            // protocol so the replica can be promoted bit-identically
            let standby = args.get("standby").map(String::from);
            let standby_interval_ms = args.get_u64("standby-interval-ms", 200)?;
            // --drain-checkpoint: where graceful drain spills live lanes
            // so a successor process can adopt them
            let drain_checkpoint = args.get_path("drain-checkpoint");
            // --peers: static membership list; enables the gossip
            // failure detector, the consistent-hash ownership guard
            // (`moved` redirects), and automatic failover
            let peers = args.get("peers").map(String::from);
            let advertise = args.get("advertise").map(String::from);
            let ping_interval_ms = args.get_u64("ping-interval-ms", 0)?;
            // --holdoff-auto: autotune each shard's coalescing window
            // from its inter-arrival EWMA (idle shards pay zero)
            let holdoff_auto = args.flag("holdoff-auto");
            // --max-models: tenant registry budget for wire-minted
            // models (absent = server default; 0 = base model only,
            // every create_model answers `model_budget`)
            let max_models =
                args.get_opt_u64("max-models")?.map(|m| m as usize);
            // --pin-cores: pin each shard's sweeper to core (i mod
            // cores) so NUMA-local planes stay local; reported per
            // shard as `pinned_cores` in `info`
            let pin_cores = args.flag("pin-cores");
            // --poll-threads: shard connections across P epoll threads
            // (event-loop transport only; 1 = the classic single poll
            // thread, bit-identical)
            let poll_threads = args.get_usize("poll-threads", 1)?.max(1);
            let listener = std::net::TcpListener::bind(addr)?;
            let bound = listener.local_addr()?;
            // the timer wheel lives in the event loop; on the threaded
            // transport (or non-Linux) a configured timeout is inert —
            // say so instead of printing it as active
            let event_loop = !threaded && cfg!(target_os = "linux");
            println!(
                "serving MSO{k} model (N={n}, {}, holdoff {holdoff_us}µs{}, shards {}, idle-timeout {}, trainer-budget {}, rebalance {}, standby {}, drain-checkpoint {}, peers {}, max-models {}, pin-cores {}, {}) on {bound} …",
                precision.name(),
                if holdoff_auto { " (auto)" } else { "" },
                match shards {
                    Some(s) => s.to_string(),
                    None => "auto".into(),
                },
                match idle_s {
                    0 => "off".into(),
                    _ if !event_loop =>
                        "off (threaded transport has no idle reaper)".into(),
                    s => format!("{s}s"),
                },
                match trainer_budget {
                    None => "unlimited".into(),
                    Some(b) => format!("{}MiB", b >> 20),
                },
                if rebalance { "on" } else { "off" },
                match &standby {
                    Some(a) => format!("{a} (every {standby_interval_ms}ms)"),
                    None => "off".into(),
                },
                match &drain_checkpoint {
                    Some(d) => d.display().to_string(),
                    None => "off".into(),
                },
                match &peers {
                    Some(p) => p.clone(),
                    None => "none".into(),
                },
                match max_models {
                    Some(m) => m.to_string(),
                    None => "default".into(),
                },
                if pin_cores { "on" } else { "off" },
                if event_loop {
                    format!("epoll event loop × {poll_threads} poll thread(s)")
                } else {
                    "thread-per-connection".into()
                }
            );
            serve_on_opts(
                listener,
                Arc::new(Model::with_precision(esn, readout, precision)),
                None,
                ServeOpts {
                    holdoff_us,
                    shards,
                    threaded,
                    idle_timeout,
                    trainer_budget,
                    rebalance,
                    standby,
                    standby_interval_ms,
                    drain_checkpoint,
                    peers,
                    advertise,
                    ping_interval_ms,
                    holdoff_auto,
                    max_models,
                    pin_cores,
                    poll_threads,
                    // operator-facing binary: SIGTERM means "drain, don't
                    // drop" (library embedders opt in via ServeOpts)
                    drain_on_sigterm: true,
                },
            )
            .map(|_| ())
        }
        "all" => {
            let quick = args.flag("quick");
            // quick mode writes *_quick.csv so it never clobbers full runs
            let sfx = if quick { "_quick" } else { "" };
            println!("== fig2 ==");
            let rows = fig2::run(&[50, 100, 200, 400], 1, true)?;
            fig2::emit(&rows, &out.join(format!("fig2{sfx}.csv")))?;
            println!("\n== fig3 ==");
            fig3::emit(&fig3::run(500, 0), &out.join(format!("fig3{sfx}.csv")))?;
            println!("\n== fig4 ==");
            fig4::emit(&fig4::run(5), &out.join(format!("fig4{sfx}.csv")))?;
            println!("\n== fig5 ==");
            fig5::emit(&fig5::run(8, 100, 0, 1e-8)?, 8, &out.join(format!("fig5{sfx}.csv")))?;
            println!("\n== table2 ==");
            let methods = MethodKind::table2_set();
            let (tasks, seeds, spec): (Vec<usize>, u64, GridSpec) = if quick {
                (vec![1, 5], 2, GridSpec::quick())
            } else {
                ((1..=12).collect(), 10, GridSpec::paper_table1())
            };
            let cells = table2::run(&tasks, &methods, seeds, spec, 100, true)?;
            table2::emit(&cells, &methods, &out.join(format!("table2{sfx}.csv")))?;
            println!("\n== fig6 ==");
            let sizes = if quick {
                vec![100]
            } else {
                vec![100, 300, 600, 1000]
            };
            let rows6 = fig6::run(&sizes, if quick { 1 } else { 3 }, 1e-7, true)?;
            fig6::emit(&rows6, &out.join(format!("fig6{sfx}.csv")))?;
            println!("\n== fig7 ==");
            let mut all7 = Vec::new();
            for &n in &sizes {
                let delay = fig6::crossing_delay(&rows6, n, "normal")
                    .unwrap_or(fig6::k_max_for(n) / 2);
                all7.extend(fig7::run(
                    n,
                    delay,
                    &fig7::connectivity_grid(),
                    if quick { 1 } else { 3 },
                    1e-7,
                    true,
                )?);
            }
            fig7::emit(&all7, &out.join(format!("fig7{sfx}.csv")))?;
            println!("\n== e2e ==");
            match run_e2e(5, 100, 0, 1e-8) {
                Ok(()) => {}
                Err(e) => println!("e2e skipped: {e:#}"),
            }
            Ok(())
        }
        other => {
            anyhow::bail!("unknown subcommand {other:?}\n{HELP}")
        }
    }
}

/// The e2e driver needs the PJRT runtime (`xla` feature).
#[cfg(feature = "xla")]
fn run_e2e(k: usize, n: usize, seed: u64, alpha: f64) -> Result<()> {
    use linear_reservoir::experiments::e2e;
    let report = e2e::run(k, n, seed, alpha)?;
    e2e::print_report(&report);
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn run_e2e(_k: usize, _n: usize, _seed: u64, _alpha: f64) -> Result<()> {
    anyhow::bail!(
        "the e2e driver runs through the compiled-HLO runtime; \
         rebuild with `--features xla` (see Cargo.toml)"
    )
}
