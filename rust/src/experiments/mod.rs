//! One driver per paper table/figure (see DESIGN.md §3 for the index).
//! Every driver writes a CSV under `results/` and prints a human-readable
//! summary; the `repro` binary dispatches to these.

pub mod ablation;
#[cfg(feature = "xla")]
pub mod e2e;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table2;

use std::path::PathBuf;

/// Results directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    std::fs::create_dir_all(&dir).ok();
    dir
}
