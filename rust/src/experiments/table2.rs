//! Table 2 — MSO1…MSO12 test RMSE for the six methods, grid-searched over
//! the Table-1 hyper-parameters, averaged over seeds.
//!
//! Expected shape (paper): Noisy Golden (σ=0.2) and Normal trade wins
//! roughly evenly; Diagonalized(EET) tracks Normal within noise; Sim never
//! takes the top rank but stays close.

use anyhow::Result;

use crate::coordinator::{GridSearch, GridSpec, MethodKind};
use crate::util::csv::CsvWriter;
use crate::util::stats::Summary;

/// Aggregated cell: one (task, method).
pub struct Cell {
    pub task: usize,
    pub method: MethodKind,
    pub mean_rmse: f64,
    pub std_rmse: f64,
    pub per_seed: Vec<f64>,
}

/// Run the full table. `tasks` ⊆ 1..=12, `seeds` = number of seeds.
pub fn run(
    tasks: &[usize],
    methods: &[MethodKind],
    seeds: u64,
    spec: GridSpec,
    n: usize,
    progress: bool,
) -> Result<Vec<Cell>> {
    let gs = GridSearch {
        spec,
        n,
        connectivity: 1.0,
    };
    let mut cells = Vec::new();
    for &k in tasks {
        for &method in methods {
            let mut per_seed = Vec::with_capacity(seeds as usize);
            for seed in 0..seeds {
                let r = gs.run_mso(k, method, seed)?;
                per_seed.push(r.test_rmse);
            }
            let s = Summary::of(&per_seed);
            if progress {
                println!(
                    "  MSO{k:<2} {:<18} rmse={:.3e} (±{:.1e})",
                    method.label(),
                    s.mean,
                    s.std
                );
            }
            cells.push(Cell {
                task: k,
                method,
                mean_rmse: s.mean,
                std_rmse: s.std,
                per_seed,
            });
        }
    }
    Ok(cells)
}

/// Emit the CSV + a paper-layout table (methods as columns, bold = best).
pub fn emit(cells: &[Cell], methods: &[MethodKind], path: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(
        path,
        &["task", "method", "mean_rmse", "std_rmse", "n_seeds"],
    )?;
    for c in cells {
        csv.rowv(&[
            &c.task,
            &c.method.label(),
            &c.mean_rmse,
            &c.std_rmse,
            &c.per_seed.len(),
        ])?;
    }
    csv.flush()?;

    // paper-layout print
    let tasks: Vec<usize> = {
        let mut t: Vec<usize> = cells.iter().map(|c| c.task).collect();
        t.sort_unstable();
        t.dedup();
        t
    };
    print!("\nTable 2 — MSO test RMSE (mean over seeds)\n{:<7}", "Task");
    for m in methods {
        print!("{:>16}", m.label());
    }
    println!();
    for &k in &tasks {
        print!("MSO{k:<4}");
        let row: Vec<&Cell> = methods
            .iter()
            .map(|m| {
                cells
                    .iter()
                    .find(|c| c.task == k && c.method == *m)
                    .expect("cell")
            })
            .collect();
        let best = row
            .iter()
            .map(|c| c.mean_rmse)
            .fold(f64::INFINITY, f64::min);
        for c in &row {
            let mark = if c.mean_rmse == best { "*" } else { " " };
            print!("{:>15.2e}{mark}", c.mean_rmse);
        }
        println!();
    }
    println!("(* = best per row)");
    Ok(())
}

/// Count wins per method (the paper's tie analysis).
pub fn wins(cells: &[Cell], methods: &[MethodKind]) -> Vec<(String, usize)> {
    let mut tasks: Vec<usize> = cells.iter().map(|c| c.task).collect();
    tasks.sort_unstable();
    tasks.dedup();
    let mut counts: Vec<(String, usize)> =
        methods.iter().map(|m| (m.label(), 0)).collect();
    for &k in &tasks {
        let row: Vec<&Cell> = methods
            .iter()
            .map(|m| {
                cells
                    .iter()
                    .find(|c| c.task == k && c.method == *m)
                    .unwrap()
            })
            .collect();
        let best_idx = row
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.mean_rmse.partial_cmp(&b.1.mean_rmse).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        counts[best_idx].1 += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_table_runs_end_to_end() {
        let methods = vec![MethodKind::Normal, MethodKind::DpgGolden { sigma: 0.0 }];
        let cells = run(&[1], &methods, 2, GridSpec::quick(), 30, false).unwrap();
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!(c.mean_rmse.is_finite());
            assert!(c.mean_rmse < 0.1, "MSO1 should be easy: {}", c.mean_rmse);
            assert_eq!(c.per_seed.len(), 2);
        }
        let w = wins(&cells, &methods);
        assert_eq!(w.iter().map(|(_, c)| c).sum::<usize>(), 1);
    }
}
