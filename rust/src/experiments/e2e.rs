//! End-to-end driver (DESIGN.md §4): the full production pipeline on a
//! real workload, with the state computation running through the COMPILED
//! HLO artifact (L1 Pallas kernel + L2 JAX graph via PJRT) — the actual
//! request path — cross-checked against the native engine, trained, and
//! evaluated.
//!
//! Reported: test RMSE (headline quality metric) and steps/sec for the
//! HLO path, the native O(N) diagonal path, and the O(N²) dense baseline.

use anyhow::{Context, Result};

use crate::coordinator::MethodKind;
use crate::linalg::Mat;
use crate::metrics::rmse;
use crate::readout::{fit, Regularizer};
use crate::reservoir::{DiagonalEsn, EsnConfig, StandardEsn};
use crate::rng::Pcg64;
use crate::runtime::DiagRuntime;
use crate::spectral::golden::{golden_spectrum, GoldenParams};
use crate::tasks::mso::{slice_rows, MsoTask};
use crate::util::Timer;

/// Everything the e2e run measures.
pub struct E2eReport {
    pub task: usize,
    pub n: usize,
    pub hlo_native_max_diff: f64,
    pub test_rmse_hlo: f64,
    pub test_rmse_native: f64,
    pub test_rmse_dense_baseline: f64,
    pub steps_per_sec_hlo: f64,
    pub steps_per_sec_native: f64,
    pub steps_per_sec_dense: f64,
}

/// Run the pipeline for MSO-`k` with an `n`-unit Noisy-Golden DPG
/// reservoir (the paper's best method), using the artifact set built by
/// `make artifacts` (needs the T=1000/slots=n shapes).
pub fn run(k: usize, n: usize, seed: u64, alpha: f64) -> Result<E2eReport> {
    let task = MsoTask::new(k);
    let splits = MsoTask::splits();
    let u = task.input_mat();
    let t_total = u.rows();

    // --- build the model (DPG: no W ever materialized) -------------------
    let config = EsnConfig::default().with_n(n).with_sr(0.9).with_seed(seed);
    let mut rng = Pcg64::new(seed, 70);
    let mut spec = golden_spectrum(n, GoldenParams { sr: 0.9, sigma: 0.2 }, &mut rng);
    // fixed-config demo (no validation sweep to reject divergent draws):
    // keep the spectrum inside the stability region — noise may push |λ|
    // past 1, which diverges over the 1000-step series
    let radius = spec.radius();
    if radius > 0.98 {
        spec = spec.scaled(0.98 / radius);
    }
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);

    // --- states through the compiled HLO artifact ------------------------
    let mut drt = DiagRuntime::open_default()
        .context("artifacts not built? run `make artifacts`")?;
    // warm-up/compile pass
    let feats_hlo = drt.run(&esn, &u, false)?;
    let t = Timer::start();
    let feats_hlo2 = drt.run(&esn, &u, false)?;
    let hlo_time = t.elapsed_s();
    drop(feats_hlo2);

    // --- native engine cross-check ---------------------------------------
    let t = Timer::start();
    let feats_native = esn.run(&u);
    let native_time = t.elapsed_s();
    let max_diff = feats_hlo.max_abs_diff(&feats_native);
    let scale = feats_native
        .data()
        .iter()
        .fold(1.0f64, |m, x| m.max(x.abs()));
    anyhow::ensure!(
        max_diff / scale < 1e-4,
        "HLO and native states diverge: {max_diff} (scale {scale})"
    );

    // --- train + evaluate through both paths -------------------------------
    let y_train = task.target_mat(splits.train.clone());
    let y_test = task.target_mat(splits.test.clone());

    let eval = |feats: &Mat| -> Result<f64> {
        let x_train = slice_rows(feats, splits.train.clone());
        let x_test = slice_rows(feats, splits.test.clone());
        let readout = fit(&x_train, &y_train, alpha, true, Regularizer::Identity)?;
        Ok(rmse(&readout.predict(&x_test), &y_test))
    };
    let test_rmse_hlo = eval(&feats_hlo)?;
    let test_rmse_native = eval(&feats_native)?;

    // --- dense O(N²) baseline for the quality + throughput contrast ------
    let baseline = StandardEsn::generate(config);
    let t = Timer::start();
    let states_dense = baseline.run(&u);
    let dense_time = t.elapsed_s();
    let test_rmse_dense_baseline = eval(&states_dense)?;

    Ok(E2eReport {
        task: k,
        n,
        hlo_native_max_diff: max_diff,
        test_rmse_hlo,
        test_rmse_native,
        test_rmse_dense_baseline,
        steps_per_sec_hlo: t_total as f64 / hlo_time.max(1e-12),
        steps_per_sec_native: t_total as f64 / native_time.max(1e-12),
        steps_per_sec_dense: t_total as f64 / dense_time.max(1e-12),
    })
}

pub fn print_report(r: &E2eReport) {
    println!("\n=== end-to-end pipeline (MSO{}, N={}) ===", r.task, r.n);
    println!("  HLO vs native state agreement : {:.3e} (max abs diff)", r.hlo_native_max_diff);
    println!("  test RMSE  — HLO path         : {:.3e}", r.test_rmse_hlo);
    println!("  test RMSE  — native path      : {:.3e}", r.test_rmse_native);
    println!("  test RMSE  — dense baseline   : {:.3e}", r.test_rmse_dense_baseline);
    println!("  throughput — HLO path         : {:.0} steps/s", r.steps_per_sec_hlo);
    println!("  throughput — native O(N) path : {:.0} steps/s", r.steps_per_sec_native);
    println!("  throughput — dense O(N²) path : {:.0} steps/s", r.steps_per_sec_dense);
    let _ = MethodKind::Normal; // (method enum reserved for future variants)
}
