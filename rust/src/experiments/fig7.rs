//! Figure 7 — memory capacity vs reservoir connectivity: Normal (explicit
//! sparse `W`) vs Diagonalization (EWT/EET of the SAME `W`), with their
//! difference, across reservoir sizes. The requested delay per size is
//! chosen so that MC ≈ 0.5 at connectivity 1 (calibrated like the paper,
//! from the Fig 6 curves).
//!
//! Expected shape (paper): both collapse at extreme sparsity; below a
//! size-dependent connectivity threshold the Diagonalization curve falls
//! UNDER the Normal baseline (the eigendecomposition degenerates — many
//! repeated/zero eigenvalues, ill-conditioned eigenbasis); above the
//! threshold the two match.

use anyhow::Result;

use crate::reservoir::{DiagonalEsn, EsnConfig, StandardEsn};
use crate::tasks::memory::McTask;
use crate::util::csv::CsvWriter;
use crate::util::stats::Summary;

pub struct Row {
    pub n: usize,
    pub connectivity: f64,
    pub delay: usize,
    pub mc_normal: f64,
    pub mc_diag: f64,
    pub difference: f64,
}

/// The connectivity sweep (log-spaced, as in the paper's x-axis).
pub fn connectivity_grid() -> Vec<f64> {
    vec![0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0]
}

/// Calibrate the per-size delay: run connectivity=1 and find the MC=0.5
/// crossing (paper's protocol: "delay chosen so MC(conn=1) = 0.5").
pub fn calibrate_delay(n: usize, seeds: u64, alpha: f64) -> Result<usize> {
    let rows = super::fig6::run(&[n], seeds, alpha, false)?;
    Ok(super::fig6::crossing_delay(&rows, n, "normal")
        .unwrap_or_else(|| super::fig6::k_max_for(n) / 2))
}

/// Run the sweep for one size with a given delay.
pub fn run(
    n: usize,
    delay: usize,
    connectivities: &[f64],
    seeds: u64,
    alpha: f64,
    progress: bool,
) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    let train = (3 * n).max(600);
    let test = n.max(300);
    for &conn in connectivities {
        let mut mc_n = Vec::new();
        let mut mc_d = Vec::new();
        for seed in 0..seeds {
            let mut task = McTask::new(train, test, seed);
            task.washout = (delay + 10).max(200);
            {
                use crate::rng::Distributions;
                let mut rng = crate::rng::Pcg64::new(seed, 3);
                task.input = rng.uniform_vec(task.washout + train + test, -0.8, 0.8);
            }
            let config = EsnConfig::default()
                .with_n(n)
                .with_sr(1.0)
                .with_connectivity(conn)
                .with_seed(seed);
            let esn = StandardEsn::generate(config);
            let u = task.input_mat();

            // Normal path
            let states_n = esn.run(&u);
            let caps_n = task.capacities_fast(&states_n, delay, alpha);
            mc_n.push(caps_n[delay - 1]);

            // Diagonalization path (EET: same W, readout trained in the
            // eigenbasis with the generalized Tikhonov of Eq. 14) — at
            // extreme sparsity the eigendecomposition degenerates (the
            // paper's threshold effect): singular eigenbasis → MC = 0, and
            // near-degenerate bases show up as numerical collapse.
            let mc = match DiagonalEsn::from_standard(&esn) {
                Ok(diag) => {
                    let states_d = diag.run(&u);
                    let qtq = diag.tikhonov_matrix().ok();
                    let caps_d = task.capacities_fast_reg(
                        &states_d,
                        delay,
                        alpha,
                        qtq.as_ref(),
                    );
                    caps_d[delay - 1]
                }
                Err(_) => 0.0,
            };
            mc_d.push(mc);
        }
        let sn = Summary::of(&mc_n);
        let sd = Summary::of(&mc_d);
        if progress {
            println!(
                "  N={n:<5} conn={conn:<6} normal={:.3} diag={:.3} diff={:+.3}",
                sn.mean,
                sd.mean,
                sn.mean - sd.mean
            );
        }
        rows.push(Row {
            n,
            connectivity: conn,
            delay,
            mc_normal: sn.mean,
            mc_diag: sd.mean,
            difference: sn.mean - sd.mean,
        });
    }
    Ok(rows)
}

pub fn emit(rows: &[Row], path: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(
        path,
        &["n", "connectivity", "delay", "mc_normal", "mc_diag", "difference"],
    )?;
    for r in rows {
        csv.rowv(&[
            &r.n,
            &r.connectivity,
            &r.delay,
            &r.mc_normal,
            &r.mc_diag,
            &r.difference,
        ])?;
    }
    csv.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shows_low_connectivity_gap() {
        // at N=60: full connectivity → diag ≈ normal; extreme sparsity →
        // diag underperforms (paper's threshold effect)
        let rows = run(60, 10, &[0.01, 1.0], 2, 1e-7, false).unwrap();
        let dense = rows.iter().find(|r| r.connectivity == 1.0).unwrap();
        assert!(
            dense.difference.abs() < 0.25,
            "dense difference {}",
            dense.difference
        );
        let sparse = rows.iter().find(|r| r.connectivity == 0.01).unwrap();
        // both degrade; diag must not beat normal by much, and typically
        // falls below it
        assert!(
            sparse.mc_diag <= sparse.mc_normal + 0.15,
            "sparse: normal={} diag={}",
            sparse.mc_normal,
            sparse.mc_diag
        );
    }
}
