//! Figure 2 — wall-clock comparison of the three processing stages
//! (generation / reservoir step / readout step) across reservoir sizes,
//! for Normal vs Diagonalization(EWT/EET) vs DPG.
//!
//! Expected shape (paper): reservoir step O(N²) vs O(N) separation growing
//! with N; Diagonalization generation ≳ Normal generation (extra eig);
//! DPG generation ≪ Diagonalization generation; readout identical.

use anyhow::Result;

use crate::bench::{bench, bench_oneshot, BenchConfig};
use crate::linalg::Mat;
use crate::readout::Readout;
use crate::reservoir::{DiagonalEsn, EsnConfig, StandardEsn};
use crate::rng::{Distributions, Pcg64};
use crate::spectral::uniform::uniform_spectrum;
use crate::util::csv::CsvWriter;

/// One measurement row.
pub struct Row {
    pub n: usize,
    pub stage: &'static str,
    pub method: &'static str,
    pub seconds: f64,
}

/// Run the Figure-2 sweep. `sizes` defaults to the paper-like range.
pub fn run(sizes: &[usize], gen_reps: usize, quick: bool) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    let cfg = if quick {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    };

    for &n in sizes {
        let config = EsnConfig::default().with_n(n).with_seed(7);

        // ---- (i) generation -------------------------------------------------
        let g_normal = bench_oneshot("gen_normal", gen_reps, || {
            StandardEsn::generate(config)
        });
        rows.push(Row {
            n,
            stage: "generation",
            method: "normal",
            seconds: g_normal.per_iter.median,
        });

        let base = StandardEsn::generate(config);
        let g_diag = bench_oneshot("gen_diagonalization", gen_reps, || {
            // diagonalization applies ON TOP of a generated standard W
            let esn = StandardEsn::generate(config);
            DiagonalEsn::from_standard(&esn).ok()
        });
        rows.push(Row {
            n,
            stage: "generation",
            method: "diagonalization",
            seconds: g_diag.per_iter.median,
        });

        let g_dpg = bench_oneshot("gen_dpg", gen_reps, || {
            let mut rng = Pcg64::new(7, 20);
            let spec = uniform_spectrum(n, 0.9, &mut rng);
            DiagonalEsn::from_dpg(spec, &config, &mut rng)
        });
        rows.push(Row {
            n,
            stage: "generation",
            method: "dpg",
            seconds: g_dpg.per_iter.median,
        });

        // ---- (ii) reservoir step --------------------------------------------
        let mut rng = Pcg64::new(7, 21);
        let u: Vec<f64> = rng.normal_vec(1);
        let r0: Vec<f64> = rng.normal_vec(n);
        let mut scratch = vec![0.0; n];
        let b_std = bench(&format!("step_normal_n{n}"), cfg, || {
            base.step(&r0, &u, &mut scratch);
            scratch[0]
        });
        rows.push(Row {
            n,
            stage: "reservoir_step",
            method: "normal",
            seconds: b_std.per_iter.median,
        });

        let mut rng2 = Pcg64::new(7, 22);
        let spec = uniform_spectrum(n, 0.9, &mut rng2);
        let diag = DiagonalEsn::from_dpg(spec, &config, &mut rng2);
        let slots = diag.spec.slots();
        let mut s_re = rng2.normal_vec(slots);
        let mut s_im = rng2.normal_vec(slots);
        let b_diag = bench(&format!("step_diagonal_n{n}"), cfg, || {
            diag.step(&mut s_re, &mut s_im, &u);
            s_re[0]
        });
        rows.push(Row {
            n,
            stage: "reservoir_step",
            method: "diagonal",
            seconds: b_diag.per_iter.median,
        });

        // ---- (iii) readout step ---------------------------------------------
        let readout = Readout {
            w: Mat::randn(n, 1, &mut rng2),
            b: vec![0.1],
        };
        let feat_mat = Mat::randn(1, n, &mut rng2);
        let b_read = bench(&format!("readout_n{n}"), cfg, || {
            readout.predict(&feat_mat)
        });
        rows.push(Row {
            n,
            stage: "readout_step",
            method: "all",
            seconds: b_read.per_iter.median,
        });
    }
    Ok(rows)
}

/// Write the CSV and print the summary table.
pub fn emit(rows: &[Row], path: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(path, &["n", "stage", "method", "seconds"])?;
    for r in rows {
        csv.rowv(&[&r.n, &r.stage, &r.method, &r.seconds])?;
    }
    csv.flush()?;
    println!("\nFig 2 — per-stage timings (median seconds)");
    println!("{:>6} {:>16} {:>18} {:>14}", "N", "stage", "method", "seconds");
    for r in rows {
        println!(
            "{:>6} {:>16} {:>18} {:>14.3e}",
            r.n, r.stage, r.method, r.seconds
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_has_expected_shape() {
        let rows = run(&[40, 120], 1, true).unwrap();
        // 6 rows per size
        assert_eq!(rows.len(), 12);
        // O(N) vs O(N²): diagonal step should win at N=120
        let std_120 = rows
            .iter()
            .find(|r| r.n == 120 && r.method == "normal" && r.stage == "reservoir_step")
            .unwrap();
        let diag_120 = rows
            .iter()
            .find(|r| r.n == 120 && r.method == "diagonal")
            .unwrap();
        assert!(
            diag_120.seconds < std_120.seconds,
            "diag {} vs std {}",
            diag_120.seconds,
            std_120.seconds
        );
    }
}
