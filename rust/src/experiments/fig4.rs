//! Figure 4 — the MSO5 series with its train/washout/validation/test
//! partition, as a CSV (t, value, split).

use anyhow::Result;

use crate::tasks::mso::{MsoTask, T_TOTAL};
use crate::util::csv::CsvWriter;

pub fn run(k: usize) -> Vec<(usize, f64, &'static str)> {
    let task = MsoTask::new(k);
    let splits = MsoTask::splits();
    (0..T_TOTAL)
        .map(|t| {
            let split = if splits.washout.contains(&t) {
                "washout"
            } else if splits.train.contains(&t) {
                "train"
            } else if splits.valid.contains(&t) {
                "valid"
            } else {
                "test"
            };
            (t, task.input[t], split)
        })
        .collect()
}

pub fn emit(rows: &[(usize, f64, &'static str)], path: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(path, &["t", "value", "split"])?;
    for (t, v, s) in rows {
        csv.rowv(&[t, v, s])?;
    }
    csv.flush()?;
    println!(
        "Fig 4 — MSO series: {} steps (100 washout / 300 train / 300 valid / 300 test)",
        rows.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_counts() {
        let rows = run(5);
        assert_eq!(rows.len(), 1000);
        let count = |s: &str| rows.iter().filter(|(_, _, x)| *x == s).count();
        assert_eq!(count("washout"), 100);
        assert_eq!(count("train"), 300);
        assert_eq!(count("valid"), 300);
        assert_eq!(count("test"), 300);
    }
}
