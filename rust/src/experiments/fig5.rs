//! Figure 5 — spectral importance: after training an MSO readout in the
//! eigenbasis, plot each eigenvalue in ℂ with marker size ∝ its readout
//! weight magnitude. Shows that the readout selects a sparse subset of
//! frequency components (the task's oscillator frequencies).

use anyhow::Result;

use crate::readout::{fit, Regularizer};
use crate::reservoir::{DiagonalEsn, EsnConfig};
use crate::rng::Pcg64;
use crate::spectral::golden::{golden_spectrum, GoldenParams};
use crate::tasks::mso::{slice_rows, MsoTask};
use crate::util::csv::CsvWriter;

pub struct Point {
    pub re: f64,
    pub im: f64,
    /// per-slot readout importance (std of the slot's contribution to the
    /// prediction), normalized to [0, 1]. Raw |w| would be misleading here
    /// because feature magnitudes vary by orders of magnitude with |λ|;
    /// importance = std_t( Σ_cols w_c·x_c(t) ) measures what the slot
    /// actually contributes to the output.
    pub weight: f64,
    /// is this a real-eigenvalue slot
    pub real_slot: bool,
}

/// Train a Noisy-Golden DPG reservoir on MSO-K and extract per-eigenvalue
/// readout importance.
pub fn run(k: usize, n: usize, seed: u64, alpha: f64) -> Result<Vec<Point>> {
    let config = EsnConfig::default()
        .with_n(n)
        .with_sr(1.0)
        .with_seed(seed);
    let mut rng = Pcg64::new(seed, 50);
    let mut spec = golden_spectrum(n, GoldenParams { sr: 1.0, sigma: 0.2 }, &mut rng);
    // keep the visualisation inside the unit disk: noise may push |λ|
    // slightly past 1, which diverges over the 1000-step series
    let radius = spec.radius();
    if radius > 1.0 {
        spec = spec.scaled(1.0 / radius);
    }
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);

    let task = MsoTask::new(k);
    let splits = MsoTask::splits();
    let feats = esn.run(&task.input_mat());
    let x = slice_rows(&feats, splits.train.clone());
    let y = task.target_mat(splits.train.clone());
    let readout = fit(&x, &y, alpha, true, Regularizer::Identity)?;

    // per-slot importance: std over train time of the slot's contribution
    // to the prediction (real slot: one column; complex slot: two columns)
    let nr = esn.spec.n_real;
    let slots = esn.spec.slots();
    let t_len = x.rows();
    let contribution_std = |cols: &[usize]| -> f64 {
        let series: Vec<f64> = (0..t_len)
            .map(|t| cols.iter().map(|&c| readout.w[(c, 0)] * x[(t, c)]).sum())
            .collect();
        let mean = series.iter().sum::<f64>() / t_len as f64;
        (series.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / t_len as f64)
            .sqrt()
    };
    let mut weights = Vec::with_capacity(slots);
    for j in 0..nr {
        weights.push(contribution_std(&[j]));
    }
    let mut col = nr;
    for _ in nr..slots {
        weights.push(contribution_std(&[col, col + 1]));
        col += 2;
    }
    let max_w = weights.iter().cloned().fold(1e-300, f64::max);

    Ok((0..slots)
        .map(|j| Point {
            re: esn.spec.lam[j].re,
            im: esn.spec.lam[j].im,
            weight: weights[j] / max_w,
            real_slot: j < nr,
        })
        .collect())
}

pub fn emit(points: &[Point], k: usize, path: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(path, &["re", "im", "weight", "real_slot"])?;
    for p in points {
        csv.rowv(&[&p.re, &p.im, &p.weight, &p.real_slot])?;
    }
    csv.flush()?;
    // report: the top-weighted eigenvalue angles vs the task's frequencies
    let mut sorted: Vec<&Point> = points.iter().collect();
    sorted.sort_by(|a, b| b.weight.partial_cmp(&a.weight).unwrap());
    println!("\nFig 5 — top spectral contributors for MSO{k}:");
    println!("{:>10} {:>10} {:>8} {:>10}", "re", "im", "weight", "angle");
    for p in sorted.iter().take(8) {
        let angle = p.im.atan2(p.re);
        println!(
            "{:>10.4} {:>10.4} {:>8.3} {:>10.4}",
            p.re, p.im, p.weight, angle
        );
    }
    println!(
        "  (MSO{k} frequencies: {:?})",
        &crate::tasks::mso::ALPHAS[..k]
    );
    Ok(())
}

/// Concentration diagnostic used by tests & EXPERIMENTS.md: the fraction
/// of total importance carried by the top `frac` share of slots. The
/// paper's Fig-5 claim is *heterogeneity* — "only a subset of eigenvalues
/// is associated with large output weights" — i.e. this number is much
/// larger than `frac` itself.
pub fn top_share(points: &[Point], frac: f64) -> f64 {
    let mut w: Vec<f64> = points.iter().map(|p| p.weight).collect();
    w.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let total: f64 = w.iter().sum();
    let k = ((w.len() as f64 * frac).ceil() as usize).max(1);
    w[..k].iter().sum::<f64>() / total.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readout_importance_is_heterogeneous() {
        let points = run(3, 100, 0, 1e-8).unwrap();
        assert!(points.len() > 50);
        // weights normalized
        assert!(points.iter().all(|p| (0.0..=1.0).contains(&p.weight)));
        // paper's claim: a small subset dominates. Top 20% of slots must
        // carry well over 20% of total importance (homogeneous would be ≈
        // equal shares).
        let share = top_share(&points, 0.2);
        assert!(share > 0.5, "top-20% share = {share}");
    }
}
