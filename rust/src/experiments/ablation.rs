//! Ablations beyond the paper's headline experiments (DESIGN.md §3 extras):
//!
//! * `noise_sweep` — Golden-noise σ sweep on MSO (does the σ=0.2 choice
//!   matter? paper only contrasts 0 vs 0.2).
//! * `eigvec_role` — same spectrum, resampled eigenvectors: quantifies the
//!   paper's "eigenvectors play a secondary role" claim on MSO.
//! * `gamma_readout` — Appendix C: training γ on the unweighted R(t)
//!   states vs the standard path.

use anyhow::Result;

use crate::coordinator::{GridSearch, GridSpec, MethodKind};
use crate::metrics::rmse;
use crate::readout::{fit, Regularizer};
use crate::reservoir::state_matrix::state_matrix_1d;
use crate::reservoir::{DiagonalEsn, EsnConfig};
use crate::rng::Pcg64;
use crate::spectral::uniform::uniform_spectrum;
use crate::tasks::mso::{slice_rows, MsoTask};
use crate::util::csv::CsvWriter;
use crate::util::stats::Summary;

/// σ sweep: mean MSO-k test RMSE per noise level.
pub fn noise_sweep(
    k: usize,
    sigmas: &[f64],
    seeds: u64,
    spec: GridSpec,
    n: usize,
) -> Result<Vec<(f64, f64, f64)>> {
    let gs = GridSearch {
        spec,
        n,
        connectivity: 1.0,
    };
    let mut out = Vec::new();
    for &sigma in sigmas {
        let mut scores = Vec::new();
        for seed in 0..seeds {
            let r = gs.run_mso(k, MethodKind::DpgGolden { sigma }, seed)?;
            scores.push(r.test_rmse);
        }
        let s = Summary::of(&scores);
        out.push((sigma, s.mean, s.std));
    }
    Ok(out)
}

/// Eigenvector role: fixed spectrum, `resamples` different eigenvector
/// draws → spread of test RMSE (low spread ⇒ vectors secondary).
pub fn eigvec_role(k: usize, n: usize, resamples: u64, alpha: f64) -> Result<Vec<f64>> {
    let task = MsoTask::new(k);
    let splits = MsoTask::splits();
    let u = task.input_mat();
    let y_train = task.target_mat(splits.train.clone());
    let y_test = task.target_mat(splits.test.clone());

    // one fixed spectrum
    let mut spec_rng = Pcg64::new(12345, 60);
    let spec = uniform_spectrum(n, 0.9, &mut spec_rng);

    let config = EsnConfig::default().with_n(n).with_sr(0.9);
    let mut out = Vec::new();
    for draw in 0..resamples {
        let mut rng = Pcg64::new(1000 + draw, 61);
        let esn = DiagonalEsn::from_dpg(spec.clone(), &config, &mut rng);
        let feats = esn.run(&u);
        let x_train = slice_rows(&feats, splits.train.clone());
        let x_test = slice_rows(&feats, splits.test.clone());
        let readout = fit(&x_train, &y_train, alpha, true, Regularizer::Identity)?;
        let pred = readout.predict(&x_test);
        out.push(rmse(&pred, &y_test));
    }
    Ok(out)
}

/// Appendix C γ-readout: train on R(t) (no W_in), recover w_out, compare
/// predictions to the standard W_in-weighted training. Returns
/// (standard_rmse, gamma_rmse).
pub fn gamma_readout(k: usize, n: usize, seed: u64, alpha: f64) -> Result<(f64, f64)> {
    let task = MsoTask::new(k);
    let splits = MsoTask::splits();
    let u = task.input_mat();
    let y_train = task.target_mat(splits.train.clone());
    let y_test = task.target_mat(splits.test.clone());

    let config = EsnConfig::default().with_n(n).with_sr(0.9).with_seed(seed);
    let mut rng = Pcg64::new(seed, 62);
    let spec = uniform_spectrum(n, 0.9, &mut rng);
    let esn = DiagonalEsn::from_dpg(spec, &config, &mut rng);

    // standard path
    let feats = esn.run(&u);
    let x_train = slice_rows(&feats, splits.train.clone());
    let x_test = slice_rows(&feats, splits.test.clone());
    let standard = fit(&x_train, &y_train, alpha, true, Regularizer::Identity)?;
    let rmse_standard = rmse(&standard.predict(&x_test), &y_test);

    // γ path: train directly on the W_in-free state matrix (Theorem 6 —
    // exact for α→0; with ridge it is a *different* regularization, which
    // is the point of the ablation)
    let sm = state_matrix_1d(&esn.spec, &task.input);
    let g = sm.gamma_features();
    let g_train = slice_rows(&g, splits.train.clone());
    let g_test = slice_rows(&g, splits.test.clone());
    let gamma = fit(&g_train, &y_train, alpha, true, Regularizer::Identity)?;
    let rmse_gamma = rmse(&gamma.predict(&g_test), &y_test);

    Ok((rmse_standard, rmse_gamma))
}

pub fn emit_noise_sweep(rows: &[(f64, f64, f64)], path: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(path, &["sigma", "mean_rmse", "std_rmse"])?;
    println!("\nAblation — Golden noise σ sweep:");
    for (sigma, mean, std) in rows {
        csv.rowv(&[sigma, mean, std])?;
        println!("  σ={sigma:<5} rmse={mean:.3e} ±{std:.1e}");
    }
    csv.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eigvec_role_spread_is_modest() {
        let scores = eigvec_role(2, 60, 4, 1e-8).unwrap();
        assert_eq!(scores.len(), 4);
        let s = Summary::of(&scores);
        // all draws solve the task; spread within ~2 orders of magnitude
        assert!(s.max < 1e-3, "max={}", s.max);
        assert!(s.max / s.min.max(1e-300) < 1e3, "spread {}..{}", s.min, s.max);
    }

    #[test]
    fn gamma_readout_solves_task() {
        let (std_rmse, gamma_rmse) = gamma_readout(2, 50, 0, 1e-9).unwrap();
        assert!(std_rmse < 1e-3);
        assert!(gamma_rmse < 1e-2, "gamma path rmse {gamma_rmse}");
    }
}
