//! Figure 6 — memory capacity `MC_k` vs delay `k` across reservoir sizes
//! (paper: N ∈ {100, 300, 600, 1000}) for Normal, DPG-Uniform, DPG-Golden
//! and DPG-Sim (spectral radius exactly 1, no leak).
//!
//! Expected shape (paper): Golden systematically above Normal at every
//! size; Uniform roughly equivalent to Normal with a more balanced
//! degradation, crossing near MC ≈ 0.5; Sim closely tracks Normal with a
//! small consistent deficit.

use anyhow::Result;

use crate::linalg::Mat;
use crate::reservoir::{DiagonalEsn, EsnConfig, StandardEsn};
use crate::rng::Pcg64;
use crate::spectral::golden::{golden_spectrum, GoldenParams};
use crate::spectral::sim::sim_spectrum;
use crate::spectral::uniform::uniform_spectrum;
use crate::tasks::memory::McTask;
use crate::util::csv::CsvWriter;
use crate::util::stats::Summary;

/// One curve point: (n, method, delay, mean MC over seeds).
pub struct Row {
    pub n: usize,
    pub method: &'static str,
    pub delay: usize,
    pub mc_mean: f64,
    pub mc_std: f64,
}

pub const METHODS: [&str; 4] = ["normal", "uniform", "golden", "sim"];

/// States at sr = 1, no leak, for one method/seed.
fn states_for(method: &str, n: usize, seed: u64, task: &McTask) -> Mat {
    let config = EsnConfig::default()
        .with_n(n)
        .with_sr(1.0)
        .with_leak(1.0)
        .with_seed(seed);
    let u = task.input_mat();
    match method {
        "normal" => StandardEsn::generate(config).run(&u),
        "uniform" => {
            let mut rng = Pcg64::new(seed, 40);
            let spec = uniform_spectrum(n, 1.0, &mut rng);
            DiagonalEsn::from_dpg(spec, &config, &mut rng).run(&u)
        }
        "golden" => {
            let mut rng = Pcg64::new(seed, 41);
            let spec =
                golden_spectrum(n, GoldenParams { sr: 1.0, sigma: 0.0 }, &mut rng);
            DiagonalEsn::from_dpg(spec, &config, &mut rng).run(&u)
        }
        "sim" => {
            let mut rng = Pcg64::new(seed, 42);
            let spec = sim_spectrum(n, 1.0, 1.0, &mut rng);
            DiagonalEsn::from_dpg(spec, &config, &mut rng).run(&u)
        }
        other => panic!("unknown method {other}"),
    }
}

/// Delay budget per size: past ~1.4·N the capacity of a linear reservoir
/// has fully collapsed (total MC ≤ N).
pub fn k_max_for(n: usize) -> usize {
    (n * 7 / 5).max(20)
}

/// Run the sweep. `sizes` e.g. `[100, 300]`; `seeds` averaged.
pub fn run(sizes: &[usize], seeds: u64, alpha: f64, progress: bool) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for &n in sizes {
        let k_max = k_max_for(n);
        // washout must cover k_max; train/test sized with N
        let train = (3 * n).max(600);
        let test = (n).max(300);
        for method in METHODS {
            // per-seed curves
            let mut curves: Vec<Vec<f64>> = Vec::new();
            for seed in 0..seeds {
                let mut task = McTask::new(train, test, seed);
                task.washout = k_max.max(200);
                // regenerate input with the right total length
                let mut rng = Pcg64::new(seed, 3);
                use crate::rng::Distributions;
                task.input = rng.uniform_vec(task.washout + train + test, -0.8, 0.8);
                let states = states_for(method, n, seed, &task);
                curves.push(task.capacities_fast(&states, k_max, alpha));
            }
            for k in 1..=k_max {
                let vals: Vec<f64> = curves.iter().map(|c| c[k - 1]).collect();
                let s = Summary::of(&vals);
                rows.push(Row {
                    n,
                    method,
                    delay: k,
                    mc_mean: s.mean,
                    mc_std: s.std,
                });
            }
            if progress {
                let total: f64 = rows
                    .iter()
                    .filter(|r| r.n == n && r.method == method)
                    .map(|r| r.mc_mean)
                    .sum();
                println!("  N={n:<5} {method:<8} total MC ≈ {total:.1}");
            }
        }
    }
    Ok(rows)
}

pub fn emit(rows: &[Row], path: &std::path::Path) -> Result<()> {
    let mut csv =
        CsvWriter::create(path, &["n", "method", "delay", "mc_mean", "mc_std"])?;
    for r in rows {
        csv.rowv(&[&r.n, &r.method, &r.delay, &r.mc_mean, &r.mc_std])?;
    }
    csv.flush()?;
    Ok(())
}

/// Delay at which the mean MC curve crosses 0.5 (used by Fig 7 to pick a
/// moderate-difficulty delay per size).
pub fn crossing_delay(rows: &[Row], n: usize, method: &str) -> Option<usize> {
    let mut curve: Vec<(usize, f64)> = rows
        .iter()
        .filter(|r| r.n == n && r.method == method)
        .map(|r| (r.delay, r.mc_mean))
        .collect();
    curve.sort_by_key(|(k, _)| *k);
    curve
        .iter()
        .find(|(_, mc)| *mc < 0.5)
        .map(|(k, _)| *k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_sane() {
        let rows = run(&[60], 1, 1e-7, false).unwrap();
        // MC near-perfect at delay 1 for every method
        for method in METHODS {
            let r1 = rows
                .iter()
                .find(|r| r.method == method && r.delay == 1)
                .unwrap();
            assert!(r1.mc_mean > 0.95, "{method} MC_1 = {}", r1.mc_mean);
            // collapse by k_max
            let rk = rows
                .iter()
                .find(|r| r.method == method && r.delay == k_max_for(60))
                .unwrap();
            assert!(rk.mc_mean < 0.6, "{method} MC_max = {}", rk.mc_mean);
        }
        // crossing exists
        assert!(crossing_delay(&rows, 60, "normal").is_some());
    }
}
