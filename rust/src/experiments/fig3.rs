//! Figure 3 — eigenvalue distributions in the complex plane: spectrum of a
//! standard random `W` vs Uniform (Alg 1) vs Golden (Alg 3, σ=0) vs Noisy
//! Golden (σ=0.2). Emits one CSV of (method, re, im) scatter points.
//!
//! Expected shape: Noisy Golden covers the unit disk more homogeneously
//! than Uniform and closely mimics the random-matrix (circular-law)
//! density; deterministic Golden shows the regular spiral.

use anyhow::Result;

use crate::linalg::eigenvalues;
use crate::rng::Pcg64;
use crate::sparse::Csr;
use crate::spectral::golden::{golden_spectrum, GoldenParams};
use crate::spectral::uniform::uniform_spectrum;
use crate::util::csv::CsvWriter;

pub struct Point {
    pub method: &'static str,
    pub re: f64,
    pub im: f64,
}

/// Generate all four spectra for reservoir size `n`.
pub fn run(n: usize, seed: u64) -> Vec<Point> {
    let mut points = Vec::new();

    // (1) standard random reservoir, scaled to unit spectral radius
    let mut rng = Pcg64::new(seed, 30);
    let w = Csr::random(n, n, 1.0, &mut rng).to_dense();
    let vals = eigenvalues(&w);
    let rho = vals.iter().map(|z| z.abs()).fold(0.0, f64::max);
    for z in &vals {
        points.push(Point {
            method: "random_w",
            re: z.re / rho,
            im: z.im / rho,
        });
    }

    // (2) uniform DPG
    let mut rng = Pcg64::new(seed, 31);
    for z in uniform_spectrum(n, 1.0, &mut rng).full() {
        points.push(Point {
            method: "uniform",
            re: z.re,
            im: z.im,
        });
    }

    // (3) golden σ=0
    let mut rng = Pcg64::new(seed, 32);
    for z in golden_spectrum(n, GoldenParams { sr: 1.0, sigma: 0.0 }, &mut rng).full() {
        points.push(Point {
            method: "golden",
            re: z.re,
            im: z.im,
        });
    }

    // (4) noisy golden σ=0.2
    let mut rng = Pcg64::new(seed, 33);
    for z in golden_spectrum(n, GoldenParams { sr: 1.0, sigma: 0.2 }, &mut rng).full() {
        points.push(Point {
            method: "noisy_golden",
            re: z.re,
            im: z.im,
        });
    }
    points
}

pub fn emit(points: &[Point], path: &std::path::Path) -> Result<()> {
    let mut csv = CsvWriter::create(path, &["method", "re", "im"])?;
    for p in points {
        csv.rowv(&[&p.method, &p.re, &p.im])?;
    }
    csv.flush()?;
    // quick density summary per method
    println!("\nFig 3 — spectral scatter ({} points)", points.len());
    for method in ["random_w", "uniform", "golden", "noisy_golden"] {
        let pts: Vec<&Point> = points.iter().filter(|p| p.method == method).collect();
        let mean_mod: f64 = pts
            .iter()
            .map(|p| (p.re * p.re + p.im * p.im).sqrt())
            .sum::<f64>()
            / pts.len() as f64;
        let real_frac = pts.iter().filter(|p| p.im.abs() < 1e-9).count() as f64
            / pts.len() as f64;
        println!(
            "  {method:<14} points={:<5} mean|λ|={mean_mod:.3} real-fraction={real_frac:.3}",
            pts.len()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_methods_n_points_each() {
        let pts = run(60, 1);
        assert_eq!(pts.len(), 4 * 60);
        for m in ["random_w", "uniform", "golden", "noisy_golden"] {
            assert_eq!(pts.iter().filter(|p| p.method == m).count(), 60);
        }
    }

    #[test]
    fn golden_more_homogeneous_than_uniform() {
        // homogeneity = no clustering: the spiral's mean nearest-neighbour
        // distance (upper-half-plane points) must exceed the uniform
        // distribution's (which clusters by chance)
        let pts = run(400, 2);
        let mean_nn = |m: &str| {
            let ps: Vec<(f64, f64)> = pts
                .iter()
                .filter(|p| p.method == m && p.im > 1e-9)
                .map(|p| (p.re, p.im))
                .collect();
            let mut total = 0.0;
            for (i, a) in ps.iter().enumerate() {
                let mut best = f64::INFINITY;
                for (j, b) in ps.iter().enumerate() {
                    if i != j {
                        let d2 = (a.0 - b.0).powi(2) + (a.1 - b.1).powi(2);
                        best = best.min(d2);
                    }
                }
                total += best.sqrt();
            }
            total / ps.len() as f64
        };
        let g = mean_nn("golden");
        let u = mean_nn("uniform");
        assert!(g > u, "golden NN {g} should exceed uniform NN {u}");
    }
}
