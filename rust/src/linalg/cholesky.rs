//! Cholesky factorization for symmetric positive-definite systems — the
//! ridge-regression normal equations `(XᵀX + αR)·W = XᵀY` (Eq. 9 / Eq. 14).

use anyhow::{bail, Result};

use super::Mat;

/// Lower-triangular Cholesky factor `A = L·Lᵀ`.
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Fails if a non-positive pivot appears (matrix
    /// not positive definite — e.g. α=0 with rank-deficient features).
    pub fn factor(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows(), a.cols());
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // rows i and j of L are contiguous prefixes — use the
                // unrolled dot kernel (perf pass: ~1.7× on the grid-search
                // solve path, see EXPERIMENTS.md §Perf)
                let (li, lj) = if i == j {
                    (l.row(i), l.row(i))
                } else {
                    // split_at guarantees disjoint borrows; j < i
                    let (top, bottom) = l.data().split_at(i * n);
                    (&bottom[..n], &top[j * n..j * n + n])
                };
                let s = a[(i, j)] - super::dense::dot(&li[..j], &lj[..j]);
                if i == j {
                    if s <= 0.0 {
                        bail!(
                            "Cholesky: non-positive pivot {s:.3e} at {i} — \
                             matrix not positive definite"
                        );
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Self { l })
    }

    /// Solve `A·x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        // L y = b
        for i in 0..n {
            let mut s = y[i];
            for k in 0..i {
                s -= self.l[(i, k)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Solve `A·X = B`.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut out = Mat::zeros(n, b.cols());
        let mut col = vec![0.0; n];
        for j in 0..b.cols() {
            for i in 0..n {
                col[i] = b[(i, j)];
            }
            let x = self.solve_vec(&col);
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        let a = Mat::randn(n, n, &mut rng);
        let mut g = a.transpose().matmul(&a);
        g.add_diag(0.1);
        g
    }

    #[test]
    fn factor_roundtrip() {
        let a = random_spd(9, 1);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l.matmul(&ch.l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_matches_lu() {
        use super::super::Lu;
        let a = random_spd(12, 2);
        let mut rng = Pcg64::seeded(3);
        use crate::rng::Distributions;
        let b = rng.normal_vec(12);
        let x1 = Cholesky::factor(&a).unwrap().solve_vec(&b);
        let x2 = Lu::factor(&a).solve_vec(&b).unwrap();
        for i in 0..12 {
            assert!((x1[i] - x2[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }
}
