//! Cholesky factorization for symmetric positive-definite systems — the
//! ridge-regression normal equations `(XᵀX + αR)·W = XᵀY` (Eq. 9 / Eq. 14).
//!
//! The factorization is **precision-generic** ([`CholeskyPrec<S>`] over
//! the sealed [`Scalar`] trait): the f32 training stack solves its normal
//! equations at f32 end-to-end, while the public f64 [`Cholesky`] wrapper
//! keeps the historical `Mat`-based API and — because the generic kernel
//! mirrors the original expression-for-expression, including the 4-way
//! unrolled dot — its exact bit behavior.

use anyhow::{bail, Result};

use crate::num::Scalar;

use super::dense::dot_prec;
use super::Mat;

/// Lower-triangular Cholesky factor `A = L·Lᵀ` at precision `S`, over a
/// row-major `[n × n]` slice (no `Mat` dependency — the f32 training
/// path assembles its systems as flat `Vec<S>`).
pub struct CholeskyPrec<S: Scalar> {
    l: Vec<S>,
    n: usize,
}

impl<S: Scalar> CholeskyPrec<S> {
    /// Factor an SPD matrix given as a row-major `[n × n]` slice. Fails
    /// if a non-positive pivot appears (matrix not positive definite —
    /// e.g. α=0 with rank-deficient features).
    pub fn factor_slice(a: &[S], n: usize) -> Result<Self> {
        assert_eq!(a.len(), n * n);
        let mut l = vec![S::ZERO; n * n];
        for i in 0..n {
            for j in 0..=i {
                // rows i and j of L are contiguous prefixes — use the
                // unrolled dot kernel (perf pass: ~1.7× on the
                // grid-search solve path, see EXPERIMENTS.md §Perf)
                let s = {
                    let (li, lj) = (&l[i * n..i * n + j], &l[j * n..j * n + j]);
                    a[i * n + j] - dot_prec(li, lj)
                };
                if i == j {
                    if s <= S::ZERO {
                        bail!(
                            "Cholesky: non-positive pivot {:.3e} at {i} — \
                             matrix not positive definite",
                            s.to_f64()
                        );
                    }
                    l[i * n + j] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        Ok(Self { l, n })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Solve `A·x = b` at `S`.
    pub fn solve_vec(&self, b: &[S]) -> Vec<S> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        // L y = b
        for i in 0..n {
            let mut s = y[i];
            for k in 0..i {
                s = s - self.l[i * n + k] * y[k];
            }
            y[i] = s / self.l[i * n + i];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s = s - self.l[k * n + i] * y[k];
            }
            y[i] = s / self.l[i * n + i];
        }
        y
    }

    /// Solve `A·X = B` for a row-major `[n × cols]` right-hand side,
    /// column by column (the same order the f64 `solve_mat` uses).
    pub fn solve_mat_slice(&self, b: &[S], cols: usize) -> Vec<S> {
        let n = self.n;
        assert_eq!(b.len(), n * cols);
        let mut out = vec![S::ZERO; n * cols];
        let mut col = vec![S::ZERO; n];
        for j in 0..cols {
            for i in 0..n {
                col[i] = b[i * cols + j];
            }
            let x = self.solve_vec(&col);
            for i in 0..n {
                out[i * cols + j] = x[i];
            }
        }
        out
    }
}

/// Lower-triangular Cholesky factor `A = L·Lᵀ` — the f64 `Mat` API
/// (a thin wrapper over [`CholeskyPrec<f64>`], bit-identical to the
/// historical implementation).
pub struct Cholesky {
    inner: CholeskyPrec<f64>,
}

impl Cholesky {
    /// Factor an SPD matrix. Fails if a non-positive pivot appears (matrix
    /// not positive definite — e.g. α=0 with rank-deficient features).
    pub fn factor(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows(), a.cols());
        Ok(Self {
            inner: CholeskyPrec::factor_slice(a.data(), a.rows())?,
        })
    }

    /// Solve `A·x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        self.inner.solve_vec(b)
    }

    /// Solve `A·X = B`.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.inner.n();
        assert_eq!(b.rows(), n);
        let flat = self.inner.solve_mat_slice(b.data(), b.cols());
        Mat::from_rows(n, b.cols(), &flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        let a = Mat::randn(n, n, &mut rng);
        let mut g = a.transpose().matmul(&a);
        g.add_diag(0.1);
        g
    }

    #[test]
    fn factor_roundtrip() {
        let a = random_spd(9, 1);
        let ch = Cholesky::factor(&a).unwrap();
        let l = Mat::from_rows(9, 9, &ch.inner.l);
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_matches_lu() {
        use super::super::Lu;
        let a = random_spd(12, 2);
        let mut rng = Pcg64::seeded(3);
        use crate::rng::Distributions;
        let b = rng.normal_vec(12);
        let x1 = Cholesky::factor(&a).unwrap().solve_vec(&b);
        let x2 = Lu::factor(&a).solve_vec(&b).unwrap();
        for i in 0..12 {
            assert!((x1[i] - x2[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn dot_prec_f64_bit_identical_to_dense_dot() {
        // the generic solve path's bit-behavior claim rests on this
        let mut rng = Pcg64::seeded(4);
        use crate::rng::Distributions;
        for n in [0usize, 1, 3, 4, 7, 16, 33] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            assert_eq!(dot_prec(&a, &b), super::super::dense::dot(&a, &b));
        }
    }

    #[test]
    fn f32_factor_solves_within_f32_tolerance() {
        let a = random_spd(10, 5);
        let mut rng = Pcg64::seeded(6);
        use crate::rng::Distributions;
        let b = rng.normal_vec(10);
        let a32: Vec<f32> = a.data().iter().map(|&x| x as f32).collect();
        let b32: Vec<f32> = b.iter().map(|&x| x as f32).collect();
        let x32 = CholeskyPrec::<f32>::factor_slice(&a32, 10)
            .unwrap()
            .solve_vec(&b32);
        let x64 = Cholesky::factor(&a).unwrap().solve_vec(&b);
        let scale = x64.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (lo, hi) in x32.iter().zip(&x64) {
            // modest-condition system: f32 solve tracks f64 loosely
            assert!(
                ((*lo as f64) - hi).abs() < 1e-2 * scale,
                "{lo} vs {hi}"
            );
        }
    }

    #[test]
    fn f64_slice_factor_bit_identical_to_mat_wrapper() {
        let a = random_spd(11, 7);
        let via_mat = Cholesky::factor(&a).unwrap();
        let via_slice = CholeskyPrec::<f64>::factor_slice(a.data(), 11).unwrap();
        assert_eq!(via_mat.inner.l, via_slice.l);
    }
}
