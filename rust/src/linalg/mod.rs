//! Dense linear algebra, from scratch (no LAPACK/nalgebra in the offline
//! registry): real and complex matrices, LU and Cholesky factorizations,
//! Householder Hessenberg reduction, Francis double-shift QR (eigenvalues)
//! and shifted-inverse-iteration eigenvectors — everything the paper's
//! diagonalization pipeline (EWT/EET/Sim) needs.
//!
//! Conventions: matrices are row-major; the reservoir equations use **row
//! vectors** (`r(t) = r(t-1)·W`), matching the paper, so "apply W to state"
//! is [`Mat::vecmat`]. The eigensolver returns *column* right-eigenvectors
//! (`W·v = λ·v`), i.e. `W = P·D·P⁻¹` with eigenvector columns in `P` — the
//! form Theorem 1 transforms with.

pub(crate) mod cdense;
mod cholesky;
pub(crate) mod dense;
mod eig;
mod hessenberg;
mod lu;

pub use cdense::CMat;
pub use cholesky::{Cholesky, CholeskyPrec};
pub use dense::Mat;
pub use eig::{eig, eigenvalues, Eig};
pub use hessenberg::hessenberg;
pub use lu::{CLu, Lu};
