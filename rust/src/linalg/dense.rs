//! Dense row-major real matrix.

use std::fmt;
use std::ops::{Index, IndexMut};

use crate::rng::{Distributions, Pcg64};

/// Dense `rows × cols` matrix of `f64`, row-major.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Build from a row-major flat slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self {
            rows,
            cols,
            data: data.to_vec(),
        }
    }

    /// i.i.d. standard normal entries (used for random reservoirs / W_in).
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let data = rng.normal_vec(rows * cols);
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self · other` with an ikj loop order (cache-friendly row-major).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &a_ik) in a_row.iter().enumerate() {
                if a_ik == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for j in 0..other.cols {
                    out_row[j] += a_ik * b_row[j];
                }
            }
        }
        out
    }

    /// Row-vector × matrix: `y = x · self` (the reservoir step direction).
    /// 4-row blocked: each pass reads four rows of `self` and writes `y`
    /// once, quartering the `y` traffic and exposing ILP (perf pass —
    /// see EXPERIMENTS.md §Perf).
    pub fn vecmat(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        let n = self.cols;
        let mut k = 0;
        while k + 4 <= self.rows {
            let (x0, x1, x2, x3) = (x[k], x[k + 1], x[k + 2], x[k + 3]);
            if x0 != 0.0 || x1 != 0.0 || x2 != 0.0 || x3 != 0.0 {
                let base = k * n;
                let r0 = &self.data[base..base + n];
                let r1 = &self.data[base + n..base + 2 * n];
                let r2 = &self.data[base + 2 * n..base + 3 * n];
                let r3 = &self.data[base + 3 * n..base + 4 * n];
                for j in 0..n {
                    y[j] += x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
                }
            }
            k += 4;
        }
        for kk in k..self.rows {
            let xk = x[kk];
            if xk == 0.0 {
                continue;
            }
            let row = self.row(kk);
            for j in 0..n {
                y[j] += xk * row[j];
            }
        }
    }

    /// Matrix × column-vector: `y = self · x`.
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self += s·I` (leak-rate mixing, ridge regularization).
    pub fn add_diag(&mut self, s: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += s;
        }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max absolute entry difference (test helper / convergence checks).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// 4-way unrolled dot product at precision `S` — measurably faster than
/// naive sum on the hot ridge/Gram paths, and deterministic. ONE kernel
/// for every precision: the f64 [`dot`] and the generic solve path
/// (`CholeskyPrec`) both delegate here, so their accumulation order can
/// never drift apart.
#[inline]
pub(crate) fn dot_prec<S: crate::num::Scalar>(a: &[S], b: &[S]) -> S {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [S::ZERO; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_prec::<f64>(a, b)
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(1);
        let a = Mat::randn(5, 5, &mut rng);
        let i = Mat::eye(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-15);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Mat::from_rows(3, 2, &[7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_associative() {
        let mut rng = Pcg64::seeded(2);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(6, 3, &mut rng);
        let c = Mat::randn(3, 5, &mut rng);
        let lhs = a.matmul(&b).matmul(&c);
        let rhs = a.matmul(&b.matmul(&c));
        assert!(lhs.max_abs_diff(&rhs) < 1e-12);
    }

    #[test]
    fn vecmat_matches_matmul() {
        let mut rng = Pcg64::seeded(3);
        let w = Mat::randn(7, 4, &mut rng);
        let x = rng.normal_vec(7);
        let mut y = vec![0.0; 4];
        w.vecmat(&x, &mut y);
        let xm = Mat::from_rows(1, 7, &x);
        let want = xm.matmul(&w);
        for j in 0..4 {
            assert!((y[j] - want[(0, j)]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_transpose_duality() {
        let mut rng = Pcg64::seeded(4);
        let a = Mat::randn(5, 3, &mut rng);
        let x = rng.normal_vec(3);
        let mut y1 = vec![0.0; 5];
        a.matvec(&x, &mut y1);
        let mut y2 = vec![0.0; 5];
        a.transpose().vecmat(&x, &mut y2);
        for i in 0..5 {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(5);
        let a = Mat::randn(4, 7, &mut rng);
        assert!(a.transpose().transpose().max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut rng = Pcg64::seeded(6);
        for n in [0, 1, 3, 4, 5, 17, 100] {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-10);
        }
    }
}
