//! Householder reduction to upper Hessenberg form: `A = Q·H·Qᵀ` with `Q`
//! orthogonal and `H` zero below the first subdiagonal. First stage of the
//! eigensolver (the QR iteration cost drops from O(N⁴) to O(N³) on
//! Hessenberg matrices); `Q` is accumulated so eigenvectors computed on `H`
//! can be transformed back to the original basis.

use super::Mat;

/// Result of a Hessenberg reduction.
pub struct HessenbergForm {
    /// Upper Hessenberg matrix `H`.
    pub h: Mat,
    /// Orthogonal accumulation `Q` with `A = Q·H·Qᵀ`.
    pub q: Mat,
}

/// Reduce `a` (square) to Hessenberg form by Householder reflections.
pub fn hessenberg(a: &Mat) -> HessenbergForm {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut h = a.clone();
    let mut q = Mat::eye(n);
    if n < 3 {
        return HessenbergForm { h, q };
    }

    // v-storage for each reflector (column k eliminates entries k+2..n)
    let mut v = vec![0.0f64; n];

    for k in 0..n - 2 {
        // Householder vector for column k, rows k+1..n
        let mut alpha = 0.0;
        for i in k + 1..n {
            alpha += h[(i, k)] * h[(i, k)];
        }
        alpha = alpha.sqrt();
        if alpha == 0.0 {
            continue;
        }
        if h[(k + 1, k)] > 0.0 {
            alpha = -alpha;
        }
        let mut vnorm2 = 0.0;
        for i in k + 1..n {
            v[i] = h[(i, k)];
            if i == k + 1 {
                v[i] -= alpha;
            }
            vnorm2 += v[i] * v[i];
        }
        if vnorm2 == 0.0 {
            continue;
        }
        let beta = 2.0 / vnorm2;

        // H ← (I - β v vᵀ) H : rows k+1..n updated
        for j in k..n {
            let mut s = 0.0;
            for i in k + 1..n {
                s += v[i] * h[(i, j)];
            }
            s *= beta;
            for i in k + 1..n {
                h[(i, j)] -= s * v[i];
            }
        }
        // H ← H (I - β v vᵀ) : cols k+1..n updated
        for i in 0..n {
            let mut s = 0.0;
            for j in k + 1..n {
                s += h[(i, j)] * v[j];
            }
            s *= beta;
            for j in k + 1..n {
                h[(i, j)] -= s * v[j];
            }
        }
        // Q ← Q (I - β v vᵀ)
        for i in 0..n {
            let mut s = 0.0;
            for j in k + 1..n {
                s += q[(i, j)] * v[j];
            }
            s *= beta;
            for j in k + 1..n {
                q[(i, j)] -= s * v[j];
            }
        }
        // clean the annihilated entries exactly
        h[(k + 1, k)] = alpha;
        for i in k + 2..n {
            h[(i, k)] = 0.0;
        }
    }
    HessenbergForm { h, q }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn h_is_hessenberg() {
        let mut rng = Pcg64::seeded(1);
        let a = Mat::randn(12, 12, &mut rng);
        let hf = hessenberg(&a);
        for i in 0..12 {
            for j in 0..12 {
                if i > j + 1 {
                    assert_eq!(hf.h[(i, j)], 0.0, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn q_is_orthogonal() {
        let mut rng = Pcg64::seeded(2);
        let a = Mat::randn(15, 15, &mut rng);
        let hf = hessenberg(&a);
        let qtq = hf.q.transpose().matmul(&hf.q);
        assert!(qtq.max_abs_diff(&Mat::eye(15)) < 1e-12);
    }

    #[test]
    fn reconstruction() {
        let mut rng = Pcg64::seeded(3);
        let a = Mat::randn(20, 20, &mut rng);
        let hf = hessenberg(&a);
        let rec = hf.q.matmul(&hf.h).matmul(&hf.q.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-11);
    }

    #[test]
    fn small_sizes_identity_q() {
        for n in [1usize, 2] {
            let mut rng = Pcg64::seeded(4);
            let a = Mat::randn(n, n, &mut rng);
            let hf = hessenberg(&a);
            assert!(hf.h.max_abs_diff(&a) < 1e-15);
            assert!(hf.q.max_abs_diff(&Mat::eye(n)) < 1e-15);
        }
    }

    #[test]
    fn already_hessenberg_unchanged_structure() {
        // tri-diagonal (symmetric) input stays Hessenberg and similar
        let n = 8;
        let a = Mat::from_fn(n, n, |i, j| {
            if i == j {
                2.0
            } else if i.abs_diff(j) == 1 {
                -1.0
            } else {
                0.0
            }
        });
        let hf = hessenberg(&a);
        let rec = hf.q.matmul(&hf.h).matmul(&hf.q.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-12);
    }
}
