//! LU factorization with partial pivoting — real ([`Lu`]) and complex
//! ([`CLu`]). Used for `P⁻¹` (EWT weight transformation), determinant-based
//! conditioning checks, and the shifted Hessenberg solves inside inverse
//! iteration.

use anyhow::{bail, Result};

use crate::num::c64;

use super::{CMat, Mat};

/// Real LU factorization `P·A = L·U` (P a row permutation).
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
    /// Number of row swaps (sign of the permutation).
    swaps: usize,
    singular: bool,
}

impl Lu {
    /// Factor. Singularity is recorded, not an error — `solve` fails, but
    /// `is_singular` lets callers degrade gracefully (the paper's Fig 7
    /// regime *wants* to observe near-singular eigenbases).
    pub fn factor(a: &Mat) -> Self {
        assert_eq!(a.rows(), a.cols());
        let n = a.rows();
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut swaps = 0;
        let mut singular = false;

        for k in 0..n {
            // pivot: largest |entry| in column k at/below diagonal
            let mut p = k;
            let mut max = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > max {
                    max = v;
                    p = i;
                }
            }
            if max == 0.0 {
                singular = true;
                continue;
            }
            if p != k {
                for j in 0..n {
                    let tmp = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
                piv.swap(k, p);
                swaps += 1;
            }
            let pivot = lu[(k, k)];
            for i in k + 1..n {
                let m = lu[(i, k)] / pivot;
                lu[(i, k)] = m;
                if m != 0.0 {
                    for j in k + 1..n {
                        let v = lu[(k, j)];
                        lu[(i, j)] -= m * v;
                    }
                }
            }
        }
        Self {
            lu,
            piv,
            swaps,
            singular,
        }
    }

    pub fn is_singular(&self) -> bool {
        self.singular
    }

    /// Reciprocal condition estimate via |pivot| ratio (cheap; adequate for
    /// the "is this eigenbasis collapsing" diagnostics of Fig 7).
    pub fn rcond_estimate(&self) -> f64 {
        let n = self.lu.rows();
        let mut min = f64::INFINITY;
        let mut max: f64 = 0.0;
        for i in 0..n {
            let p = self.lu[(i, i)].abs();
            min = min.min(p);
            max = max.max(p);
        }
        if max == 0.0 {
            0.0
        } else {
            min / max
        }
    }

    pub fn det(&self) -> f64 {
        if self.singular {
            return 0.0;
        }
        let mut d = if self.swaps % 2 == 0 { 1.0 } else { -1.0 };
        for i in 0..self.lu.rows() {
            d *= self.lu[(i, i)];
        }
        d
    }

    /// Solve `A·x = b` in place.
    pub fn solve_vec(&self, b: &[f64]) -> Result<Vec<f64>> {
        if self.singular {
            bail!("LU: matrix is singular");
        }
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // forward: L y = Pb
        for i in 1..n {
            let mut s = x[i];
            for j in 0..i {
                s -= self.lu[(i, j)] * x[j];
            }
            x[i] = s;
        }
        // backward: U x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in i + 1..n {
                s -= self.lu[(i, j)] * x[j];
            }
            x[i] = s / self.lu[(i, i)];
        }
        Ok(x)
    }

    /// Solve `A·X = B` column-wise.
    pub fn solve_mat(&self, b: &Mat) -> Result<Mat> {
        let n = self.lu.rows();
        assert_eq!(b.rows(), n);
        let mut out = Mat::zeros(n, b.cols());
        let mut col = vec![0.0; n];
        for j in 0..b.cols() {
            for i in 0..n {
                col[i] = b[(i, j)];
            }
            let x = self.solve_vec(&col)?;
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        Ok(out)
    }

    pub fn inverse(&self) -> Result<Mat> {
        self.solve_mat(&Mat::eye(self.lu.rows()))
    }
}

/// Complex LU factorization with partial pivoting.
pub struct CLu {
    lu: CMat,
    piv: Vec<usize>,
    singular: bool,
}

impl CLu {
    pub fn factor(a: &CMat) -> Self {
        assert_eq!(a.rows(), a.cols());
        let n = a.rows();
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut singular = false;

        for k in 0..n {
            let mut p = k;
            let mut max = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > max {
                    max = v;
                    p = i;
                }
            }
            if max == 0.0 {
                singular = true;
                continue;
            }
            if p != k {
                for j in 0..n {
                    let tmp = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
                piv.swap(k, p);
            }
            let pivot = lu[(k, k)];
            for i in k + 1..n {
                let m = lu[(i, k)] / pivot;
                lu[(i, k)] = m;
                if m != c64::ZERO {
                    for j in k + 1..n {
                        let v = lu[(k, j)];
                        lu[(i, j)] -= m * v;
                    }
                }
            }
        }
        Self { lu, piv, singular }
    }

    pub fn is_singular(&self) -> bool {
        self.singular
    }

    pub fn rcond_estimate(&self) -> f64 {
        let n = self.lu.rows();
        let mut min = f64::INFINITY;
        let mut max: f64 = 0.0;
        for i in 0..n {
            let p = self.lu[(i, i)].abs();
            min = min.min(p);
            max = max.max(p);
        }
        if max == 0.0 {
            0.0
        } else {
            min / max
        }
    }

    pub fn solve_vec(&self, b: &[c64]) -> Result<Vec<c64>> {
        if self.singular {
            bail!("CLU: matrix is singular");
        }
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        let mut x: Vec<c64> = self.piv.iter().map(|&p| b[p]).collect();
        for i in 1..n {
            let mut s = x[i];
            for j in 0..i {
                s -= self.lu[(i, j)] * x[j];
            }
            x[i] = s;
        }
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in i + 1..n {
                s -= self.lu[(i, j)] * x[j];
            }
            x[i] = s / self.lu[(i, i)];
        }
        Ok(x)
    }

    pub fn solve_mat(&self, b: &CMat) -> Result<CMat> {
        let n = self.lu.rows();
        assert_eq!(b.rows(), n);
        let mut out = CMat::zeros(n, b.cols());
        let mut col = vec![c64::ZERO; n];
        for j in 0..b.cols() {
            for i in 0..n {
                col[i] = b[(i, j)];
            }
            let x = self.solve_vec(&col)?;
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        Ok(out)
    }

    pub fn inverse(&self) -> Result<CMat> {
        self.solve_mat(&CMat::eye(self.lu.rows()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distributions, Pcg64};

    #[test]
    fn solve_recovers_known_solution() {
        let mut rng = Pcg64::seeded(1);
        let a = Mat::randn(8, 8, &mut rng);
        let x_true = rng.normal_vec(8);
        let mut b = vec![0.0; 8];
        a.matvec(&x_true, &mut b);
        let x = Lu::factor(&a).solve_vec(&b).unwrap();
        for i in 0..8 {
            assert!((x[i] - x_true[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let mut rng = Pcg64::seeded(2);
        let a = Mat::randn(10, 10, &mut rng);
        let inv = Lu::factor(&a).inverse().unwrap();
        assert!(a.matmul(&inv).max_abs_diff(&Mat::eye(10)) < 1e-9);
    }

    #[test]
    fn det_of_triangular() {
        let a = Mat::from_rows(3, 3, &[2.0, 1.0, 0.0, 0.0, 3.0, 5.0, 0.0, 0.0, 4.0]);
        assert!((Lu::factor(&a).det() - 24.0).abs() < 1e-12);
    }

    #[test]
    fn det_sign_under_swap() {
        let a = Mat::from_rows(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        assert!((Lu::factor(&a).det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        let lu = Lu::factor(&a);
        assert!(lu.is_singular());
        assert!(lu.solve_vec(&[1.0, 0.0]).is_err());
        assert_eq!(lu.det(), 0.0);
    }

    #[test]
    fn complex_solve_roundtrip() {
        let mut rng = Pcg64::seeded(3);
        let a = CMat::from_fn(6, 6, |_, _| c64::new(rng.normal(), rng.normal()));
        let x_true: Vec<c64> =
            (0..6).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        let mut b = vec![c64::ZERO; 6];
        for i in 0..6 {
            for j in 0..6 {
                b[i] += a[(i, j)] * x_true[j];
            }
        }
        let x = CLu::factor(&a).solve_vec(&b).unwrap();
        for i in 0..6 {
            assert!((x[i] - x_true[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn complex_inverse() {
        let mut rng = Pcg64::seeded(4);
        let a = CMat::from_fn(7, 7, |_, _| c64::new(rng.normal(), rng.normal()));
        let inv = CLu::factor(&a).inverse().unwrap();
        assert!(a.matmul(&inv).max_abs_diff(&CMat::eye(7)) < 1e-9);
    }

    #[test]
    fn rcond_sane() {
        let well = Mat::eye(5);
        assert!((Lu::factor(&well).rcond_estimate() - 1.0).abs() < 1e-12);
        let mut ill = Mat::eye(5);
        ill[(4, 4)] = 1e-14;
        assert!(Lu::factor(&ill).rcond_estimate() < 1e-10);
    }
}
