//! Non-symmetric eigendecomposition, from scratch.
//!
//! Pipeline (same family as LAPACK `dgeev` / EISPACK):
//! 1. [`hessenberg`] — Householder reduction `A = Q·H·Qᵀ`.
//! 2. [`francis_eigenvalues`] — Francis implicit double-shift QR on `H`
//!    (adapted from the classic EISPACK `hqr` routine): all eigenvalues of
//!    a real matrix as real values + complex-conjugate pairs.
//! 3. Eigenvectors by shifted **inverse iteration** on the *Hessenberg*
//!    matrix (EISPACK `invit` strategy): each solve is O(N²) thanks to the
//!    Hessenberg structure, so all N eigenvectors cost O(N³) total; the
//!    vectors are rotated back through `Q`.
//!
//! Degenerate spectra (the extreme-sparsity regime of the paper's Fig 7 —
//! many repeated eigenvalues, near-defective `W`) do not panic: inverse
//! iteration perturbs exactly-singular shifts and the caller can inspect
//! [`Eig::max_residual`] / the basis conditioning to observe the collapse,
//! which is precisely the phenomenon Fig 7 measures.

use crate::num::c64;

use super::hessenberg::hessenberg;
use super::{CMat, Mat};

/// Full eigendecomposition `A = P·diag(λ)·P⁻¹` (columns of `p` are right
/// eigenvectors, unit 2-norm).
pub struct Eig {
    /// Eigenvalues, in the order produced by the QR iteration; conjugate
    /// pairs are adjacent with the `im > 0` member first.
    pub values: Vec<c64>,
    /// Right eigenvector matrix (columns match `values`).
    pub p: CMat,
    /// Max residual `‖A·v − λ·v‖₂` over all eigenpairs (each `v` unit-norm).
    pub max_residual: f64,
}

/// Eigenvalues only (Hessenberg + Francis QR), O(N³), no eigenvectors.
pub fn eigenvalues(a: &Mat) -> Vec<c64> {
    let hf = hessenberg(a);
    let mut h = hf.h;
    francis_eigenvalues(&mut h)
}

/// Full eigendecomposition. See module docs for the algorithm.
pub fn eig(a: &Mat) -> Eig {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let hf = hessenberg(a);
    let mut h_work = hf.h.clone();
    let values = francis_eigenvalues(&mut h_work);

    // ---- eigenvectors by inverse iteration on H --------------------------
    let anorm = hf.h.frobenius().max(1e-300);
    let mut p = CMat::zeros(n, n);
    let mut k = 0;
    while k < n {
        let lam = values[k];
        let v_h = inverse_iteration(&hf.h, lam, anorm, k as u64);
        // rotate back: v = Q · v_h
        let v = rotate(&hf.q, &v_h);
        p.set_col(k, &v);
        if lam.im != 0.0 && k + 1 < n && (values[k + 1] - lam.conj()).abs() < 1e-8 * anorm.max(1.0)
        {
            // conjugate partner: v̄ (A real ⇒ A·v̄ = λ̄·v̄)
            let vbar: Vec<c64> = v.iter().map(|z| z.conj()).collect();
            p.set_col(k + 1, &vbar);
            k += 2;
        } else {
            k += 1;
        }
    }

    // ---- residual check ---------------------------------------------------
    let ac = CMat::from_real(a);
    let mut max_residual: f64 = 0.0;
    for (j, &lam) in values.iter().enumerate() {
        let v = p.col(j);
        let mut r: f64 = 0.0;
        for i in 0..n {
            let mut av = c64::ZERO;
            for l in 0..n {
                av += ac[(i, l)] * v[l];
            }
            r += (av - lam * v[i]).norm_sqr();
        }
        max_residual = max_residual.max(r.sqrt());
    }

    Eig {
        values,
        p,
        max_residual,
    }
}

/// Rotate a Hessenberg-basis vector back to the original basis (`Q · v`).
fn rotate(q: &Mat, v: &[c64]) -> Vec<c64> {
    let n = q.rows();
    let mut out = vec![c64::ZERO; n];
    for i in 0..n {
        let row = q.row(i);
        let mut s = c64::ZERO;
        for j in 0..n {
            s += v[j] * row[j];
        }
        out[i] = s;
    }
    // normalize to unit 2-norm with a deterministic phase (largest
    // component real-positive) so results are reproducible.
    normalize(&mut out);
    out
}

fn normalize(v: &mut [c64]) {
    let norm = v.iter().map(|z| z.norm_sqr()).sum::<f64>().sqrt();
    if norm == 0.0 {
        return;
    }
    // phase fix: rotate so the max-|.| component is real positive
    let mut best = 0;
    let mut best_mod = 0.0;
    for (i, z) in v.iter().enumerate() {
        if z.abs() > best_mod {
            best_mod = z.abs();
            best = i;
        }
    }
    let phase = v[best] / c64::real(v[best].abs());
    let scale = phase.conj() * (1.0 / norm);
    for z in v.iter_mut() {
        *z = *z * scale;
    }
}

/// Inverse iteration for one eigenvalue on the Hessenberg matrix.
fn inverse_iteration(h: &Mat, lam: c64, anorm: f64, seed: u64) -> Vec<c64> {
    use crate::rng::{Distributions, Pcg64};
    let n = h.rows();
    // perturb the shift slightly off the exact eigenvalue so (H - λI) is
    // merely ill-conditioned, not singular — the classic invit trick.
    let eps = 1e-10 * anorm.max(1.0);
    let shift = lam + c64::new(eps, eps * 0.5);

    let mut rng = Pcg64::new(0xE16E_57A7 ^ seed, seed);
    let mut b: Vec<c64> = (0..n)
        .map(|_| c64::new(rng.normal(), rng.normal()))
        .collect();
    normalize(&mut b);

    let solver = HessShiftSolve::factor(h, shift);
    let mut v = b.clone();
    for _ in 0..3 {
        v = solver.solve(&b);
        let norm = v.iter().map(|z| z.norm_sqr()).sum::<f64>().sqrt();
        if !norm.is_finite() || norm == 0.0 {
            // singular to working precision — keep previous direction
            v = b.clone();
            break;
        }
        for z in v.iter_mut() {
            *z = *z * (1.0 / norm);
        }
        b = v.clone();
    }
    normalize(&mut v);
    v
}

/// LU-style factorization of `(H − σI)` exploiting Hessenberg structure:
/// elimination touches only the single subdiagonal, with adjacent-row
/// pivoting → O(N²) factor, O(N²) memory (upper triangle + one band).
struct HessShiftSolve {
    /// row-major complex storage of the eliminated matrix (upper triangular
    /// + recorded multipliers on the subdiagonal slots)
    u: CMat,
    mult: Vec<c64>,
    swapped: Vec<bool>,
}

impl HessShiftSolve {
    fn factor(h: &Mat, sigma: c64) -> Self {
        let n = h.rows();
        let mut u = CMat::from_fn(n, n, |i, j| {
            let v = c64::real(h[(i, j)]);
            if i == j {
                v - sigma
            } else {
                v
            }
        });
        let mut mult = vec![c64::ZERO; n];
        let mut swapped = vec![false; n];
        for k in 0..n.saturating_sub(1) {
            let below = u[(k + 1, k)];
            if below == c64::ZERO {
                continue;
            }
            if below.abs() > u[(k, k)].abs() {
                // swap rows k, k+1 (adjacent pivoting suffices: only one
                // nonzero below the diagonal in a Hessenberg matrix)
                for j in k..n {
                    let tmp = u[(k, j)];
                    u[(k, j)] = u[(k + 1, j)];
                    u[(k + 1, j)] = tmp;
                }
                swapped[k] = true;
            }
            let pivot = u[(k, k)];
            let pivot = if pivot.abs() < 1e-300 {
                c64::new(1e-300, 0.0)
            } else {
                pivot
            };
            let m = u[(k + 1, k)] / pivot;
            mult[k] = m;
            u[(k + 1, k)] = c64::ZERO;
            if m != c64::ZERO {
                for j in k + 1..n {
                    let ukj = u[(k, j)];
                    u[(k + 1, j)] -= m * ukj;
                }
            }
        }
        Self { u, mult, swapped }
    }

    fn solve(&self, b: &[c64]) -> Vec<c64> {
        let n = b.len();
        let mut x = b.to_vec();
        // forward pass replaying swaps + multipliers
        for k in 0..n.saturating_sub(1) {
            if self.swapped[k] {
                x.swap(k, k + 1);
            }
            let m = self.mult[k];
            if m != c64::ZERO {
                let xk = x[k];
                x[k + 1] -= m * xk;
            }
        }
        // back substitution
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in i + 1..n {
                s -= self.u[(i, j)] * x[j];
            }
            let d = self.u[(i, i)];
            let d = if d.abs() < 1e-300 {
                c64::new(1e-300, 0.0)
            } else {
                d
            };
            x[i] = s / d;
        }
        x
    }
}

/// Francis implicit double-shift QR on an upper Hessenberg matrix
/// (in-place; destroys `h`). Classic EISPACK `hqr`, 0-indexed.
pub(crate) fn francis_eigenvalues(h: &mut Mat) -> Vec<c64> {
    let n = h.rows();
    let mut wr = vec![0.0f64; n];
    let mut wi = vec![0.0f64; n];

    // overall norm for deflation thresholds
    let mut anorm = 0.0f64;
    for i in 0..n {
        for j in i.saturating_sub(1)..n {
            anorm += h[(i, j)].abs();
        }
    }
    if anorm == 0.0 {
        return vec![c64::ZERO; n];
    }

    let mut nn = n as isize - 1;
    let mut t = 0.0f64; // accumulated exceptional shift
    while nn >= 0 {
        let mut its = 0;
        loop {
            // find small subdiagonal: l in 0..=nn with h[l][l-1] negligible
            let mut l = nn;
            while l >= 1 {
                let s = h[(l as usize - 1, l as usize - 1)].abs()
                    + h[(l as usize, l as usize)].abs();
                let s = if s == 0.0 { anorm } else { s };
                if h[(l as usize, l as usize - 1)].abs() <= f64::EPSILON * s {
                    h[(l as usize, l as usize - 1)] = 0.0;
                    break;
                }
                l -= 1;
            }
            let x = h[(nn as usize, nn as usize)];
            if l == nn {
                // one real root found
                wr[nn as usize] = x + t;
                wi[nn as usize] = 0.0;
                nn -= 1;
                break;
            }
            let y = h[(nn as usize - 1, nn as usize - 1)];
            let w = h[(nn as usize, nn as usize - 1)]
                * h[(nn as usize - 1, nn as usize)];
            if l == nn - 1 {
                // 2x2 block: two roots
                let p = 0.5 * (y - x);
                let q = p * p + w;
                let z = q.abs().sqrt();
                let x_t = x + t;
                if q >= 0.0 {
                    // real pair
                    let z = p + if p >= 0.0 { z } else { -z };
                    wr[nn as usize] = x_t + z;
                    wr[nn as usize - 1] = wr[nn as usize];
                    if z != 0.0 {
                        wr[nn as usize] = x_t - w / z;
                    }
                    wi[nn as usize] = 0.0;
                    wi[nn as usize - 1] = 0.0;
                } else {
                    // complex conjugate pair — store im>0 member FIRST
                    wr[nn as usize - 1] = x_t + p;
                    wr[nn as usize] = x_t + p;
                    wi[nn as usize - 1] = z;
                    wi[nn as usize] = -z;
                }
                nn -= 2;
                break;
            }
            // no convergence yet: QR sweep
            if its == 30 || its == 20 {
                // exceptional shift
                t += x;
                for i in 0..=nn as usize {
                    h[(i, i)] -= x;
                }
                let s = h[(nn as usize, nn as usize - 1)].abs()
                    + h[(nn as usize - 1, nn as usize - 2)].abs();
                let y2 = 0.75 * s;
                let w2 = -0.4375 * s * s;
                do_francis_sweep(h, l as usize, nn as usize, y2, y2, w2);
            } else {
                if its >= 60 {
                    // give up on this block: take the diagonal as the root
                    // (degenerate/defective input — documented behaviour)
                    wr[nn as usize] = x + t;
                    wi[nn as usize] = 0.0;
                    nn -= 1;
                    break;
                }
                do_francis_sweep(h, l as usize, nn as usize, x, y, w);
            }
            its += 1;
        }
    }

    (0..n).map(|i| c64::new(wr[i], wi[i])).collect()
}

/// One implicit double-shift QR sweep on rows/cols `l..=nn` with shift data
/// derived from trailing elements (x = h[nn][nn], y = h[nn-1][nn-1],
/// w = h[nn][nn-1]*h[nn-1][nn]).
fn do_francis_sweep(h: &mut Mat, l: usize, nn: usize, x: f64, y: f64, w: f64) {
    let n = h.rows();
    // find m: start of the bulge chase
    let mut m = nn - 2;
    let (mut p, mut q, mut r);
    loop {
        let z = h[(m, m)];
        let rr = x - z;
        let ss = y - z;
        p = (rr * ss - w) / h[(m + 1, m)] + h[(m, m + 1)];
        q = h[(m + 1, m + 1)] - z - rr - ss;
        r = h[(m + 2, m + 1)];
        let s = p.abs() + q.abs() + r.abs();
        if s != 0.0 {
            p /= s;
            q /= s;
            r /= s;
        }
        if m == l {
            break;
        }
        let u = h[(m, m - 1)].abs() * (q.abs() + r.abs());
        let v = p.abs()
            * (h[(m - 1, m - 1)].abs() + z.abs() + h[(m + 1, m + 1)].abs());
        if u <= f64::EPSILON * v {
            break;
        }
        m -= 1;
    }
    for i in m + 2..=nn {
        h[(i, i - 2)] = 0.0;
        if i != m + 2 {
            h[(i, i - 3)] = 0.0;
        }
    }
    // double QR step: chase the bulge from m to nn-1
    for k in m..nn {
        if k != m {
            p = h[(k, k - 1)];
            q = h[(k + 1, k - 1)];
            r = if k != nn - 1 { h[(k + 2, k - 1)] } else { 0.0 };
            let x2 = p.abs() + q.abs() + r.abs();
            if x2 != 0.0 {
                p /= x2;
                q /= x2;
                r /= x2;
            } else {
                continue;
            }
        }
        let mut s = (p * p + q * q + r * r).sqrt();
        if p < 0.0 {
            s = -s;
        }
        if s == 0.0 {
            continue;
        }
        if k == m {
            if l != m {
                h[(k, k - 1)] = -h[(k, k - 1)];
            }
        } else {
            h[(k, k - 1)] = -s * {
                let x2 = h[(k, k - 1)].abs() + h[(k + 1, k - 1)].abs()
                    + if k != nn - 1 {
                        h[(k + 2, k - 1)].abs()
                    } else {
                        0.0
                    };
                x2
            };
        }
        p += s;
        let x2 = p / s;
        let y2 = q / s;
        let z2 = r / s;
        q /= p;
        r /= p;
        // row modification
        for j in k..n.min(nn + 1) {
            let mut pp = h[(k, j)] + q * h[(k + 1, j)];
            if k != nn - 1 {
                pp += r * h[(k + 2, j)];
            }
            h[(k, j)] -= pp * x2;
            h[(k + 1, j)] -= pp * y2;
            if k != nn - 1 {
                h[(k + 2, j)] -= pp * z2;
            }
        }
        // column modification
        let upper = if nn < k + 3 { nn } else { k + 3 };
        for i in l..=upper {
            let mut pp = x2 * h[(i, k)] + y2 * h[(i, k + 1)];
            if k != nn - 1 {
                pp += z2 * h[(i, k + 2)];
            }
            h[(i, k)] -= pp;
            h[(i, k + 1)] -= pp * q;
            if k != nn - 1 {
                h[(i, k + 2)] -= pp * r;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn sorted_reals(mut vals: Vec<f64>) -> Vec<f64> {
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let vals = eigenvalues(&a);
        let mut re: Vec<f64> = vals.iter().map(|z| z.re).collect();
        re.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (i, v) in re.iter().enumerate() {
            assert!((v - (i + 1) as f64).abs() < 1e-10, "{re:?}");
        }
        assert!(vals.iter().all(|z| z.im.abs() < 1e-12));
    }

    #[test]
    fn rotation_matrix_complex_pair() {
        let th = 0.7f64;
        let a = Mat::from_rows(2, 2, &[th.cos(), -th.sin(), th.sin(), th.cos()]);
        let vals = eigenvalues(&a);
        assert_eq!(vals.len(), 2);
        let mut ims: Vec<f64> = vals.iter().map(|z| z.im).collect();
        ims.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((ims[0] + th.sin()).abs() < 1e-12);
        assert!((ims[1] - th.sin()).abs() < 1e-12);
        for v in vals {
            assert!((v.abs() - 1.0).abs() < 1e-12);
            assert!((v.re - th.cos()).abs() < 1e-12);
        }
    }

    #[test]
    fn companion_matrix_known_roots() {
        // x³ - 6x² + 11x - 6 = (x-1)(x-2)(x-3)
        let a = Mat::from_rows(3, 3, &[6.0, -11.0, 6.0, 1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        let vals = eigenvalues(&a);
        let re = sorted_reals(vals.iter().map(|z| z.re).collect());
        for (got, want) in re.iter().zip([1.0, 2.0, 3.0]) {
            assert!((got - want).abs() < 1e-8, "{re:?}");
        }
    }

    #[test]
    fn trace_and_det_invariants() {
        let mut rng = Pcg64::seeded(5);
        for n in [3usize, 8, 17] {
            let a = Mat::randn(n, n, &mut rng);
            let vals = eigenvalues(&a);
            let trace: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let sum: c64 = vals.iter().fold(c64::ZERO, |s, &z| s + z);
            assert!((sum.re - trace).abs() < 1e-8 * n as f64, "n={n}");
            assert!(sum.im.abs() < 1e-8, "n={n}");
            let det = super::super::Lu::factor(&a).det();
            let prod = vals.iter().fold(c64::ONE, |p, &z| p * z);
            assert!(
                (prod.re - det).abs() < 1e-6 * det.abs().max(1.0),
                "n={n} prod={prod:?} det={det}"
            );
        }
    }

    #[test]
    fn conjugate_pairs_adjacent_and_closed() {
        let mut rng = Pcg64::seeded(6);
        let a = Mat::randn(20, 20, &mut rng);
        let vals = eigenvalues(&a);
        let mut i = 0;
        while i < vals.len() {
            if vals[i].im.abs() > 1e-12 {
                assert!(i + 1 < vals.len());
                assert!((vals[i + 1] - vals[i].conj()).abs() < 1e-9);
                assert!(vals[i].im > 0.0, "im>0 member first");
                i += 2;
            } else {
                i += 1;
            }
        }
    }

    #[test]
    fn full_eig_residual_small_random() {
        let mut rng = Pcg64::seeded(7);
        for n in [5usize, 12, 30] {
            let mut a = Mat::randn(n, n, &mut rng);
            a.scale(1.0 / (n as f64).sqrt());
            let e = eig(&a);
            assert!(
                e.max_residual < 1e-6,
                "n={n} residual={}",
                e.max_residual
            );
        }
    }

    #[test]
    fn full_eig_reconstruction() {
        let mut rng = Pcg64::seeded(8);
        let n = 16;
        let mut a = Mat::randn(n, n, &mut rng);
        a.scale(1.0 / (n as f64).sqrt());
        let e = eig(&a);
        // A ≈ P · diag(λ) · P⁻¹
        let mut pd = e.p.clone();
        for j in 0..n {
            for i in 0..n {
                let v = pd[(i, j)];
                pd[(i, j)] = v * e.values[j];
            }
        }
        let pinv = super::super::CLu::factor(&e.p).inverse().unwrap();
        let rec = pd.matmul(&pinv);
        let rec_err = rec.real_part().max_abs_diff(&a);
        let imag_leak = rec.imag_part().frobenius();
        assert!(rec_err < 1e-7, "rec_err={rec_err}");
        assert!(imag_leak < 1e-7, "imag={imag_leak}");
    }

    #[test]
    fn symmetric_matrix_real_spectrum() {
        let mut rng = Pcg64::seeded(9);
        let b = Mat::randn(10, 10, &mut rng);
        let a = {
            let mut s = b.matmul(&b.transpose());
            s.scale(0.1);
            s
        };
        let vals = eigenvalues(&a);
        for v in &vals {
            assert!(v.im.abs() < 1e-8, "{v:?}");
            assert!(v.re > -1e-10); // PSD
        }
    }

    #[test]
    fn eigenvalue_count_always_n() {
        let mut rng = Pcg64::seeded(10);
        for n in 1..25usize {
            let a = Mat::randn(n, n, &mut rng);
            assert_eq!(eigenvalues(&a).len(), n);
        }
    }

    #[test]
    fn handles_degenerate_sparse_matrix_without_panic() {
        // mostly-zero matrix: heavily repeated zero eigenvalue (Fig 7 regime)
        let mut a = Mat::zeros(12, 12);
        a[(0, 1)] = 0.5;
        a[(3, 7)] = -0.2;
        let e = eig(&a);
        assert_eq!(e.values.len(), 12);
        // spectrum is all zeros (nilpotent)
        for v in &e.values {
            assert!(v.abs() < 1e-6, "{v:?}");
        }
    }

    #[test]
    fn spectral_radius_of_scaled_matrix() {
        let mut rng = Pcg64::seeded(11);
        let n = 40;
        let mut a = Mat::randn(n, n, &mut rng);
        a.scale(1.0 / (n as f64).sqrt()); // circular law: ρ ≈ 1
        let rho = eigenvalues(&a)
            .iter()
            .map(|z| z.abs())
            .fold(0.0, f64::max);
        assert!((rho - 1.0).abs() < 0.35, "rho={rho}");
    }
}
