//! Dense row-major complex matrix (eigenvector bases `P`, `P⁻¹`, and the
//! transformed weights of Theorem 1).

use std::fmt;
use std::ops::{Index, IndexMut};

use crate::num::c64;

use super::Mat;

/// Dense `rows × cols` complex matrix, row-major.
#[derive(Clone, PartialEq)]
pub struct CMat {
    rows: usize,
    cols: usize,
    data: Vec<c64>,
}

impl CMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![c64::ZERO; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = c64::ONE;
        }
        m
    }

    /// Lift a real matrix.
    pub fn from_real(a: &Mat) -> Self {
        let mut m = Self::zeros(a.rows(), a.cols());
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                m[(i, j)] = c64::real(a[(i, j)]);
            }
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> c64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[c64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [c64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<c64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[c64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> CMat {
        CMat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Real part as a [`Mat`].
    pub fn real_part(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self[(i, j)].re)
    }

    /// Imaginary part as a [`Mat`].
    pub fn imag_part(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |i, j| self[(i, j)].im)
    }

    pub fn matmul(&self, other: &CMat) -> CMat {
        assert_eq!(self.cols, other.rows, "cmatmul shape mismatch");
        let mut out = CMat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a_ik = self[(i, k)];
                if a_ik == c64::ZERO {
                    continue;
                }
                let b_row = other.row(k);
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for j in 0..other.cols {
                    out_row[j] += a_ik * b_row[j];
                }
            }
        }
        out
    }

    /// Row-vector × matrix (`[r]_P = r · P` — the paper's transformation).
    pub fn vecmat(&self, x: &[c64], y: &mut [c64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(c64::ZERO);
        for (k, &xk) in x.iter().enumerate() {
            if xk == c64::ZERO {
                continue;
            }
            let row = self.row(k);
            for j in 0..self.cols {
                y[j] += xk * row[j];
            }
        }
    }

    pub fn max_abs_diff(&self, other: &CMat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f64::max)
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|z| z.norm_sqr()).sum::<f64>().sqrt()
    }
}

impl Index<(usize, usize)> for CMat {
    type Output = c64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &c64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for CMat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut c64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for CMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "CMat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(6) {
                write!(f, "{:?} ", self[(i, j)])?;
            }
            writeln!(f)?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn crandn(rows: usize, cols: usize, seed: u64) -> CMat {
        use crate::rng::Distributions;
        let mut rng = Pcg64::seeded(seed);
        CMat::from_fn(rows, cols, |_, _| c64::new(rng.normal(), rng.normal()))
    }

    #[test]
    fn matmul_identity() {
        let a = crandn(5, 5, 1);
        assert!(a.matmul(&CMat::eye(5)).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn matmul_matches_real_on_real_inputs() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seeded(2);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(6, 3, &mut rng);
        let want = a.matmul(&b);
        let got = CMat::from_real(&a).matmul(&CMat::from_real(&b));
        assert!(got.real_part().max_abs_diff(&want) < 1e-12);
        assert!(got.imag_part().frobenius() < 1e-14);
    }

    #[test]
    fn vecmat_row_convention() {
        let a = crandn(3, 4, 3);
        let x = [c64::new(1.0, 0.5), c64::new(-2.0, 0.0), c64::new(0.0, 1.0)];
        let mut y = vec![c64::ZERO; 4];
        a.vecmat(&x, &mut y);
        for j in 0..4 {
            let mut want = c64::ZERO;
            for i in 0..3 {
                want += x[i] * a[(i, j)];
            }
            assert!((y[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn col_roundtrip() {
        let mut a = CMat::zeros(4, 2);
        let v: Vec<c64> = (0..4).map(|i| c64::new(i as f64, -1.0)).collect();
        a.set_col(1, &v);
        assert_eq!(a.col(1), v);
        assert_eq!(a.col(0), vec![c64::ZERO; 4]);
    }
}
